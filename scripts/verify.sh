#!/usr/bin/env bash
# Tier-1 verification on CPU: install dev extras (best-effort — the
# property tests self-skip if hypothesis is unavailable) and run the suite
# with jax pinned to the CPU backend so Pallas kernels take the interpret
# path.
#
# Usage: scripts/verify.sh [--bench [BENCH_tag.json]] [extra pytest args]
#
#   (always) after the tests, the cross-process persistence smoke runs
#             against a tmpdir store (scripts/persistence_smoke.py):
#             write + Autopilot layout in process A, reopen + shuffle
#             elision + bit-identical results in process B.
#
#   (always) then the serving-tier stress smoke (scripts/serving_stress.py,
#             small-N, time-boxed): concurrent clients vs one store with a
#             background thread flipping layout generations — every result
#             must match the serial baseline bit-for-bit, zero failures.
#
#   --bench   before the bench run, the skew-adaptive smoke
#             (scripts/skew_smoke.py) drives the full DESIGN §12 loop:
#             Zipf tables → Autopilot salt tick and rebucket tick, padding
#             waste must drop, consumer results must stay bit-identical.
#
#   --bench   after the tests, run the benchmark suite in smoke mode
#             (LACHESIS_BENCH_SMOKE=1: synthetic inputs shrunk to CI size;
#             the headline device-repartition rows keep their full N so the
#             perf trajectory stays comparable across BENCH_*.json
#             snapshots).  Writes BENCH_smoke.json unless a path is given.
#             The snapshot includes the plan_compile_vs_exec and
#             plan_cached_rerun_* rows (planner/executor split, DESIGN §9).
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_JSON=""
RUN_BENCH=0
if [[ "${1:-}" == "--bench" ]]; then
    RUN_BENCH=1
    shift
    if [[ "${1:-}" == *.json ]]; then
        BENCH_JSON="$1"
        shift
    else
        BENCH_JSON="BENCH_smoke.json"
    fi
fi

python -m pip install -q -r requirements-dev.txt || \
    echo "WARN: dev deps not installed (offline?) — property tests will skip"

# Lint gate (critical rules only — see ruff.toml).  Skipped with a warning
# when ruff is unavailable (offline container); CI always installs it.
if python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src tests
else
    echo "WARN: ruff not installed — lint gate skipped"
fi

JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"

# Durable storage tier (DESIGN §10): run the cross-process persistence
# smoke against a throwaway tmpdir store — process A writes + lets the
# Autopilot pick the layout, process B (a fresh interpreter) reopens and
# must elide its shuffle with bit-identical results.
SMOKE_STORE="$(mktemp -d)"
trap 'rm -rf "$SMOKE_STORE"' EXIT
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/persistence_smoke.py write "$SMOKE_STORE"
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/persistence_smoke.py reopen "$SMOKE_STORE"

# Serving tier (DESIGN §11): time-boxed concurrency stress — N clients,
# background generation flips, bit-identical to the serial baseline.
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/serving_stress.py 10 8

# Cluster tier (DESIGN §14): cross-process smoke — sharded write over two
# directory-nodes, a rebalance killed mid-stream (before the epoch
# commit), then a fresh process must recover the consistent epoch,
# complete the scale-out inside the incremental bytes-moved bound, and
# serve bit-identically from the survivors after a node's files vanish.
CLUSTER_STORE="$(mktemp -d)"
trap 'rm -rf "$SMOKE_STORE" "$CLUSTER_STORE"' EXIT
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/cluster_smoke.py write "$CLUSTER_STORE"
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/cluster_smoke.py crash "$CLUSTER_STORE"
JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/cluster_smoke.py reopen "$CLUSTER_STORE"

if [[ "$RUN_BENCH" == 1 ]]; then
    # skew-adaptive loop smoke (DESIGN §12): salt + rebucket ticks must
    # shrink padding waste with bit-identical consumer results
    echo "== skew smoke"
    JAX_PLATFORMS=cpu LACHESIS_BENCH_SMOKE=1 \
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python scripts/skew_smoke.py

    echo "== bench smoke → $BENCH_JSON"
    JAX_PLATFORMS=cpu LACHESIS_BENCH_SMOKE=1 \
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.run --json "$BENCH_JSON"

    # advisory diff vs the newest committed snapshot (DESIGN §15) —
    # never gates: CI noise + cross-machine snapshots make hard limits
    # meaningless here; the per-machine gate is the telemetry watchdog
    echo "== bench diff (advisory)"
    python scripts/bench_diff.py "$BENCH_JSON" || true
fi
