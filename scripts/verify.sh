#!/usr/bin/env bash
# Tier-1 verification on CPU: install dev extras (best-effort — the
# property tests self-skip if hypothesis is unavailable) and run the suite
# with jax pinned to the CPU backend so Pallas kernels take the interpret
# path.
#
# Usage: scripts/verify.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt || \
    echo "WARN: dev deps not installed (offline?) — property tests will skip"

JAX_PLATFORMS=cpu PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"
