"""Cross-process persistence smoke test (DESIGN §10 acceptance scenario).

Two phases, run as SEPARATE processes sharing one store directory:

    python scripts/persistence_smoke.py write  /path/to/store
    python scripts/persistence_smoke.py reopen /path/to/store

``write`` (process A): builds a round-robin dataset, runs the consumer
workload under an attached Autopilot until it applies the hash layout the
workload wants, and saves the run's result table next to the store.

``reopen`` (process B): a fresh interpreter reattaches via
``Session(store_path=...)``, runs the same consumer, and asserts

* the partition node is ELIDED (zero shuffles performed, zero bytes), and
* the result is bit-identical to process A's saved table —

i.e. the paper's headline: a second application rides the partitioning a
previous application paid for.  Exit code 0 on success, 1 with a reason on
any violated invariant.  Wired into scripts/verify.sh and the CI job
(which persists the store directory between two workflow steps).
"""

from __future__ import annotations

import os
import sys

import numpy as np

from repro.api import Session
from repro.core import Workload
from repro.core.executor import TableVal
from repro.service.observer import LogicalClock

NUM_WORKERS = 4
N_ROWS = 20_000


def consumer() -> Workload:
    wl = Workload("smoke-consumer")
    t = wl.scan("events")
    p = wl.partition(t["k"])
    wl.aggregate(p, reducer="sum")
    return wl


def final_table(res) -> TableVal:
    return [v for v in res.values.values() if isinstance(v, TableVal)][-1]


def expected_path(store_dir: str) -> str:
    return os.path.join(store_dir, "smoke_expected.npz")


def fail(msg: str):
    print(f"PERSISTENCE SMOKE FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def phase_write(store_dir: str) -> None:
    rng = np.random.default_rng(7)
    data = {"k": rng.integers(0, 257, size=N_ROWS).astype(np.int64),
            "v": rng.standard_normal(N_ROWS).astype(np.float32)}
    sess = Session(store_path=store_dir, num_workers=NUM_WORKERS)
    sess.write("events", data)              # round-robin: the "wrong" layout
    ap = sess.autopilot(clock=LogicalClock())

    first = sess.run(consumer())
    if first.stats.shuffles_performed != 1:
        fail(f"expected the first run to shuffle once, got "
             f"{first.stats.shuffles_performed}")
    sess.run(consumer())
    report = ap.tick()
    if [d.dataset for d in report.applied] != ["events"]:
        fail(f"Autopilot did not apply the events layout: {report.applied}")

    res = sess.run(consumer())
    if res.stats.shuffles_elided != 1 or res.stats.shuffles_performed != 0:
        fail("post-apply run did not elide its shuffle")
    table = final_table(res)
    np.savez(expected_path(store_dir),
             counts=np.asarray(table.counts),
             **{f"col_{k}": np.asarray(v) for k, v in table.columns.items()})
    decisions = sess.store.durable.decisions()
    print(f"phase A OK: layout {decisions[-1]['candidate']!r} applied at "
          f"gen {decisions[-1]['generation']}, expected table saved "
          f"({table.num_rows} rows)")


def phase_reopen(store_dir: str) -> None:
    sess = Session(store_path=store_dir)
    if sess.num_workers != NUM_WORKERS:
        fail(f"catalog worker count not adopted: {sess.num_workers}")
    stored = sess.read("events")
    if stored.partitioner is None or not stored.partitioner.is_keyed:
        fail("reopened dataset lost its keyed partitioner identity")

    res = sess.run(consumer())
    if res.stats.shuffles_elided != 1:
        fail(f"reopened session did not elide the shuffle "
             f"(elided={res.stats.shuffles_elided})")
    if res.stats.shuffles_performed != 0 or res.stats.shuffle_bytes != 0:
        fail(f"reopened session still shuffled: "
             f"performed={res.stats.shuffles_performed} "
             f"bytes={res.stats.shuffle_bytes}")

    table = final_table(res)
    want = np.load(expected_path(store_dir))
    if not np.array_equal(want["counts"], np.asarray(table.counts)):
        fail("per-worker counts differ from process A")
    for k, v in table.columns.items():
        w = want[f"col_{k}"]
        got = np.asarray(v)
        if w.dtype != got.dtype or not np.array_equal(w, got):
            fail(f"column {k!r} not bit-identical to process A")
    print(f"phase B OK: fresh process elided its shuffle "
          f"(0 shuffle bytes) and reproduced process A's "
          f"{table.num_rows}-row result bit-identically")


def main() -> None:
    if len(sys.argv) != 3 or sys.argv[1] not in ("write", "reopen"):
        sys.exit("usage: persistence_smoke.py {write|reopen} STORE_DIR")
    phase, store_dir = sys.argv[1], sys.argv[2]
    if phase == "write":
        phase_write(store_dir)
    else:
        phase_reopen(store_dir)


if __name__ == "__main__":
    main()
