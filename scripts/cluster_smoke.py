"""Cross-process cluster smoke test (DESIGN §14 acceptance scenario).

Three phases, run as SEPARATE processes sharing one store directory:

    python scripts/cluster_smoke.py write   /path/to/store
    python scripts/cluster_smoke.py crash   /path/to/store
    python scripts/cluster_smoke.py reopen  /path/to/store

``write`` (process A): creates a two-node cluster store (directories as
nodes, replication 2), writes datasets sharded across both nodes, and
saves the expected bits next to the store.

``crash`` (process B): reopens, starts an incremental rebalance onto a
third node, and dies mid-stream — after the first dataset's segments
moved but BEFORE the epoch pointer flipped (``abort_after=1``).  The
"killed" node's partial directory is torn away to simulate losing it.

``reopen`` (process C): a fresh interpreter must recover to the last
consistent epoch (the pre-rebalance placement), read every dataset
bit-identically, then complete a clean rebalance and — after node A's
files are deleted outright — serve everything from the survivors.

Exit code 0 on success, 1 with a reason on any violated invariant.
Wired into scripts/verify.sh and the CI job (which persists the store
directory between workflow steps).
"""

from __future__ import annotations

import os
import shutil
import sys

import numpy as np

from repro.api import Session
from repro.cluster import ClusterConfig, RebalanceAborted

NUM_WORKERS = 8
NODES = ("node-a", "node-b")
DATASETS = ("events", "metrics")


def expected_path(root: str) -> str:
    return os.path.join(root, "smoke_expected.npz")


def fail(msg: str):
    print(f"CLUSTER SMOKE FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def canonical(store, name):
    return {k: np.asarray(v) for k, v in store.read(name).gather().items()}


def check_bits(store, expected):
    for name in DATASETS:
        got = canonical(store, name)
        for col, want in expected[name].items():
            if not np.array_equal(got[col], want):
                fail(f"{name}.{col} is not bit-identical after reopen")


def phase_write(root: str) -> None:
    rng = np.random.default_rng(14)
    sess = Session(store_path=root, num_workers=NUM_WORKERS,
                   cluster=ClusterConfig(nodes=NODES, replication=2))
    expected = {}
    for i, name in enumerate(DATASETS):
        data = {"k": rng.integers(0, 997, 4000).astype(np.int64),
                "v": rng.standard_normal(4000).astype(np.float32)}
        sess.store.write(name, data)
        expected[name] = canonical(sess.store, name)
    for node in NODES:
        if not os.path.isdir(os.path.join(root, "nodes", node)):
            fail(f"{node} holds no segments after the sharded persist")
    if sess.store.placement_epoch != 0:
        fail(f"fresh store should sit at epoch 0, got "
             f"{sess.store.placement_epoch}")
    np.savez(expected_path(root),
             **{f"{n}/{c}": v for n, cols in expected.items()
                for c, v in cols.items()})
    print(f"cluster smoke write OK: {len(DATASETS)} datasets over "
          f"{len(NODES)} nodes, epoch 0")


def phase_crash(root: str) -> None:
    sess = Session(store_path=root, num_workers=NUM_WORKERS)
    if not sess.store.is_cluster:
        fail("reopen did not detect the cluster store")
    plan = sess.plan_rebalance(add_nodes=("node-c",), reason="smoke-crash")
    if plan.partitions_moved <= 0:
        fail("scale-out plan moved no partitions")
    try:
        sess.rebalance(plan=plan, abort_after=1)
    except RebalanceAborted as e:
        print(f"cluster smoke crash OK: {e}")
    else:
        fail("abort_after=1 did not abort before the epoch commit")
    if sess.store.placement_epoch != 0:
        fail("aborted rebalance must leave the epoch unflipped")
    # the new node dies mid-rebalance: its half-streamed segments vanish
    shutil.rmtree(os.path.join(root, "nodes", "node-c"),
                  ignore_errors=True)


def phase_reopen(root: str) -> None:
    with np.load(expected_path(root)) as z:
        expected = {}
        for key in z.files:
            name, col = key.split("/", 1)
            expected.setdefault(name, {})[col] = z[key]

    sess = Session(store_path=root, num_workers=NUM_WORKERS)
    store = sess.store
    if store.placement_epoch != 0:
        fail(f"recovery must land on the pre-crash epoch 0, got "
             f"{store.placement_epoch}")
    if set(store.directory.nodes) != set(NODES):
        fail(f"recovered membership {store.directory.nodes} != {NODES}")
    check_bits(store, expected)

    # the interrupted scale-out now completes cleanly...
    res = sess.rebalance(add_nodes=("node-c",), reason="smoke-retry")
    if res.epoch != 1:
        fail(f"clean rebalance should commit epoch 1, got {res.epoch}")
    total = sum(float(store.read(n).padded_bytes) for n in DATASETS)
    bound = res.partitions_moved / NUM_WORKERS * total
    if res.bytes_moved > bound + 1e-9:
        fail(f"incremental bound violated: moved {res.bytes_moved} B > "
             f"{bound:.0f} B ({res.partitions_moved}/{NUM_WORKERS} of "
             f"{total:.0f} B)")
    check_bits(store, expected)

    # ...and losing a whole original node leaves every partition served
    del sess, store
    shutil.rmtree(os.path.join(root, "nodes", "node-a"))
    sess2 = Session(store_path=root, num_workers=NUM_WORKERS)
    if sess2.store.placement_epoch != 1:
        fail("post-rebalance reopen lost the committed epoch")
    check_bits(sess2.store, expected)
    print(f"cluster smoke reopen OK: epoch {sess2.store.placement_epoch}, "
          f"moved {res.partitions_moved}/{NUM_WORKERS} partitions "
          f"({res.bytes_moved} B ≤ {bound:.0f} B bound), survivors serve "
          f"bit-identically")


def main() -> None:
    if len(sys.argv) != 3 or sys.argv[1] not in ("write", "crash", "reopen"):
        print(__doc__)
        sys.exit(2)
    phase, root = sys.argv[1], sys.argv[2]
    {"write": phase_write, "crash": phase_crash,
     "reopen": phase_reopen}[phase](root)


if __name__ == "__main__":
    main()
