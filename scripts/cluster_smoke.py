"""Cross-process cluster smoke test (DESIGN §14 + §15 acceptance).

Three phases, run as SEPARATE processes sharing one store directory:

    python scripts/cluster_smoke.py write   /path/to/store
    python scripts/cluster_smoke.py crash   /path/to/store
    python scripts/cluster_smoke.py reopen  /path/to/store

``write`` (process A): creates a two-node cluster store (directories as
nodes, replication 2), writes datasets sharded across both nodes, runs a
consumer workload (seeding the durable telemetry history), and saves the
expected bits next to the store.

``crash`` (process B): reopens, starts an incremental rebalance onto a
third node, and dies mid-stream — after the first dataset's segments
moved but BEFORE the epoch pointer flipped (``abort_after=1``).  The
"killed" node's partial directory is torn away to simulate losing it.

``reopen`` (process C): a fresh interpreter must recover to the last
consistent epoch (the pre-rebalance placement), read every dataset
bit-identically, then complete a clean rebalance and — after node A's
files are deleted outright — serve everything from the survivors.

Observability (DESIGN §15): each phase traces itself under a process
label, chains onto the previous phase's serialized ``TraceContext``
(persisted under ``<store>/telemetry/``), spills its spans — the crash
phase from *inside* the dying rebalance, so the open ``cluster.rebalance``
span survives — and exports its metrics registry as a per-node snapshot.
The reopen phase then stitches everything into ONE Perfetto-loadable
trace (``telemetry/cluster_trace.json``) plus a merged node-labeled
metrics view (``cluster_metrics.json`` / ``.prom``) and machine-checks
both: spans from all three processes under one trace, paired flow
events across each process boundary, the crashed rebalance flagged
``incomplete``, and per-run telemetry records surviving both restarts.

Exit code 0 on success, 1 with a reason on any violated invariant.
Wired into scripts/verify.sh and the CI job (which persists the store
directory between workflow steps and uploads the stitched artifacts).
"""

from __future__ import annotations

import json
import os
import shutil
import sys

import numpy as np

import repro.obs as obs
from repro.api import Session
from repro.cluster import ClusterConfig, RebalanceAborted
from repro.core import Workload

NUM_WORKERS = 8
NODES = ("node-a", "node-b")
DATASETS = ("events", "metrics")
PHASES = ("write", "crash", "reopen")


def expected_path(root: str) -> str:
    return os.path.join(root, "smoke_expected.npz")


def fail(msg: str):
    print(f"CLUSTER SMOKE FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def canonical(store, name):
    return {k: np.asarray(v) for k, v in store.read(name).gather().items()}


def check_bits(store, expected):
    for name in DATASETS:
        got = canonical(store, name)
        for col, want in expected[name].items():
            if not np.array_equal(got[col], want):
                fail(f"{name}.{col} is not bit-identical after reopen")


def consumer() -> Workload:
    wl = Workload("cluster-smoke-q")
    t = wl.scan("events")
    p = wl.partition(t["k"])
    wl.aggregate(p, reducer="sum")
    return wl


def phase_write(root: str) -> None:
    rng = np.random.default_rng(14)
    sess = Session(store_path=root, num_workers=NUM_WORKERS,
                   cluster=ClusterConfig(nodes=NODES, replication=2))
    tele = sess.telemetry_store
    with obs.span("cluster_smoke.write", "smoke"):
        # persist our context NOW: the next phase (another process)
        # attaches to it through the wire carrier
        tele.save_trace_context(obs.TRACER.context(), "write")
        expected = {}
        for name in DATASETS:
            data = {"k": rng.integers(0, 997, 4000).astype(np.int64),
                    "v": rng.standard_normal(4000).astype(np.float32)}
            sess.store.write(name, data)
            expected[name] = canonical(sess.store, name)
        for node in NODES:
            if not os.path.isdir(os.path.join(root, "nodes", node)):
                fail(f"{node} holds no segments after the sharded persist")
        if sess.store.placement_epoch != 0:
            fail(f"fresh store should sit at epoch 0, got "
                 f"{sess.store.placement_epoch}")
        # seed the durable telemetry: one consumer run = one RunProfile
        sess.run(consumer())
        if len(sess.telemetry()) < 1:
            fail("run produced no telemetry RunProfile record")
        np.savez(expected_path(root),
                 **{f"{n}/{c}": v for n, cols in expected.items()
                    for c, v in cols.items()})
    obs.spill_spans(tele.dir, "write")
    sess.export_node_metrics("write")
    print(f"cluster smoke write OK: {len(DATASETS)} datasets over "
          f"{len(NODES)} nodes, epoch 0, "
          f"{len(sess.telemetry())} telemetry record(s)")


def phase_crash(root: str) -> None:
    sess = Session(store_path=root, num_workers=NUM_WORKERS)
    if not sess.store.is_cluster:
        fail("reopen did not detect the cluster store")
    tele = sess.telemetry_store
    ctx = tele.load_trace_context("write")
    if ctx is None:
        fail("write phase left no trace-context carrier")
    with obs.TRACER.attach(ctx):
        with obs.span("cluster_smoke.crash", "smoke"):
            tele.save_trace_context(obs.TRACER.context(), "crash")
            plan = sess.plan_rebalance(add_nodes=("node-c",),
                                       reason="smoke-crash")
            if plan.partitions_moved <= 0:
                fail("scale-out plan moved no partitions")

            def on_abort():
                # the process "dies" here: spill with the
                # cluster.rebalance span still OPEN on the stack, the
                # way a crash handler would
                obs.spill_spans(tele.dir, "crash")
                sess.export_node_metrics("crash")

            try:
                sess.rebalance(plan=plan, abort_after=1, on_abort=on_abort)
            except RebalanceAborted as e:
                print(f"cluster smoke crash OK: {e}")
            else:
                fail("abort_after=1 did not abort before the epoch commit")
    # no spill after this point: the crash dump above IS this process's
    # trace, exactly as if the interpreter never got further
    if sess.store.placement_epoch != 0:
        fail("aborted rebalance must leave the epoch unflipped")
    # the new node dies mid-rebalance: its half-streamed segments vanish
    shutil.rmtree(os.path.join(root, "nodes", "node-c"),
                  ignore_errors=True)


def check_cluster_trace(doc) -> None:
    """Machine check over the stitched trace: spans from all three
    processes under one document, paired flows (every ``s`` has its
    ``f``) including one cross-process arrow per phase boundary, and the
    crashed rebalance present as an ``incomplete`` complete-event."""
    other = doc.get("otherData", {})
    procs = other.get("processes", {})
    if set(procs) != set(PHASES):
        fail(f"merged trace has processes {sorted(procs)}, want {PHASES}")
    events = doc.get("traceEvents", [])
    by_pid = {}
    for ev in events:
        if ev.get("ph") == "X":
            by_pid.setdefault(ev["pid"], []).append(ev)
    for proc, pid in procs.items():
        if not by_pid.get(pid):
            fail(f"no spans from process {proc!r} in the merged trace")
    starts = [ev for ev in events if ev.get("ph") == "s"]
    finishes = [ev for ev in events if ev.get("ph") == "f"]
    if len(starts) != len(finishes):
        fail(f"unpaired flows: {len(starts)} starts, "
             f"{len(finishes)} finishes")
    if {ev["id"] for ev in starts} != {ev["id"] for ev in finishes}:
        fail("flow start/finish ids do not pair up")
    cross = other.get("cross_process_flows", 0)
    if cross < 2:      # write→crash and crash→reopen at minimum
        fail(f"expected >= 2 cross-process flows, got {cross}")
    # each cross-process arrow must actually span two pids
    xproc = [ev for ev in starts if ev.get("name") == "xproc"]
    fin_by_id = {ev["id"]: ev for ev in finishes}
    for ev in xproc:
        if fin_by_id[ev["id"]]["pid"] == ev["pid"]:
            fail("cross-process flow starts and finishes on one pid")
    # the crashed rebalance survived as an incomplete span
    reb = [ev for ev in events
           if ev.get("ph") == "X" and ev.get("name") == "cluster.rebalance"
           and ev.get("args", {}).get("incomplete")
           and ev.get("args", {}).get("process") == "crash"]
    if not reb:
        fail("open cluster.rebalance span from the crash is missing")
    if other.get("incomplete", 0) < 1:
        fail("merged trace reports no incomplete spans")


def phase_reopen(root: str) -> None:
    with np.load(expected_path(root)) as z:
        expected = {}
        for key in z.files:
            name, col = key.split("/", 1)
            expected.setdefault(name, {})[col] = z[key]

    sess = Session(store_path=root, num_workers=NUM_WORKERS)
    tele = sess.telemetry_store
    ctx = tele.load_trace_context("crash")
    if ctx is None:
        fail("crash phase left no trace-context carrier")
    res = None
    with obs.TRACER.attach(ctx):
        with obs.span("cluster_smoke.reopen", "smoke"):
            tele.save_trace_context(obs.TRACER.context(), "reopen")
            store = sess.store
            if store.placement_epoch != 0:
                fail(f"recovery must land on the pre-crash epoch 0, got "
                     f"{store.placement_epoch}")
            if set(store.directory.nodes) != set(NODES):
                fail(f"recovered membership {store.directory.nodes} != "
                     f"{NODES}")
            check_bits(store, expected)

            # the interrupted scale-out now completes cleanly...
            res = sess.rebalance(add_nodes=("node-c",), reason="smoke-retry")
            if res.epoch != 1:
                fail(f"clean rebalance should commit epoch 1, "
                     f"got {res.epoch}")
            total = sum(float(store.read(n).padded_bytes) for n in DATASETS)
            bound = res.partitions_moved / NUM_WORKERS * total
            if res.bytes_moved > bound + 1e-9:
                fail(f"incremental bound violated: moved {res.bytes_moved} "
                     f"B > {bound:.0f} B ({res.partitions_moved}/"
                     f"{NUM_WORKERS} of {total:.0f} B)")
            check_bits(store, expected)

            # ...and losing a whole original node leaves every partition
            # served
            del sess, store
            shutil.rmtree(os.path.join(root, "nodes", "node-a"))
            sess2 = Session(store_path=root, num_workers=NUM_WORKERS)
            if sess2.store.placement_epoch != 1:
                fail("post-rebalance reopen lost the committed epoch")
            check_bits(sess2.store, expected)

            # durable telemetry: the write phase's RunProfile must still
            # be here, and this process's run must append beside it
            sess2.run(consumer())
            profiles = sess2.telemetry()
            if len(profiles) < 2:
                fail(f"telemetry lost records across restarts: "
                     f"{len(profiles)} < 2")
            seen = {p.process for p in profiles}
            if not {"write", "reopen"} <= seen:
                fail(f"telemetry processes {sorted(seen)} missing a phase")
    obs.spill_spans(tele.dir, "reopen")
    sess2.export_node_metrics("reopen")

    # stitch the three per-process spills into ONE trace + machine-check
    trace_path = os.path.join(tele.dir, "cluster_trace.json")
    doc = obs.write_merged_trace(trace_path, tele.dir,
                                 metadata={"smoke": "cluster"})
    check_cluster_trace(doc)

    # merged node-labeled metrics view, strictly parseable
    merged = sess2.cluster_metrics()
    if set(merged.get("nodes", [])) != set(PHASES):
        fail(f"cluster metrics merged nodes {merged.get('nodes')} != "
             f"{PHASES}")
    text = sess2.cluster_metrics_text()
    parsed = obs.parse_prometheus_text(text)      # raises on violations
    nodes_seen = {lab.get("node") for _n, lab, _v in parsed["samples"]
                  if "node" in lab}
    if not set(PHASES) <= nodes_seen:
        fail(f"node labels {sorted(nodes_seen)} missing a phase")
    with open(os.path.join(tele.dir, "cluster_metrics.json"), "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
    with open(os.path.join(tele.dir, "cluster_metrics.prom"), "w") as f:
        f.write(text)

    print(f"cluster smoke reopen OK: epoch {sess2.store.placement_epoch}, "
          f"moved {res.partitions_moved}/{NUM_WORKERS} partitions "
          f"({res.bytes_moved} B ≤ {bound:.0f} B bound), survivors serve "
          f"bit-identically; stitched trace "
          f"{doc['otherData']['spans']} spans / "
          f"{doc['otherData']['cross_process_flows']} cross-process flows "
          f"/ {doc['otherData']['incomplete']} incomplete -> {trace_path}; "
          f"{len(profiles)} telemetry records across 3 processes")


def main() -> None:
    if len(sys.argv) != 3 or sys.argv[1] not in PHASES:
        print(__doc__)
        sys.exit(2)
    phase, root = sys.argv[1], sys.argv[2]
    # full tracing under the phase's process label: the merge step needs
    # each spill to identify which process its spans came from
    obs.enable("full", process=phase)
    {"write": phase_write, "crash": phase_crash,
     "reopen": phase_reopen}[phase](root)


if __name__ == "__main__":
    main()
