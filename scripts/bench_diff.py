"""Diff a fresh benchmark JSON against the previous snapshot (DESIGN §15).

    python scripts/bench_diff.py BENCH_fresh.json [BENCH_baseline.json]
                                 [--tolerance 1.25] [--strict]

With no explicit baseline the newest committed ``BENCH_*.json`` in the
repo root (by mtime, excluding the fresh file itself) is used.  Rows are
matched by ``name``; for each shared row the ratio
``fresh.us_per_call / baseline.us_per_call`` is printed, with rows past
the tolerance flagged ``REGRESSED`` (slower) / ``improved`` (faster).

This is a REPORT, not a gate: CI machines are noisy and the committed
snapshots come from different hardware, so the exit code is 0 no matter
what the diff says — unless ``--strict`` is passed (exit 1 on any
flagged regression), which is for local before/after comparisons on one
machine.  The durable, per-machine regression gate is the
RegressionDetector over the telemetry history (src/repro/obs/watchdog.py),
not this script.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_rows(path: str):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("rows", []):
        name, us = row.get("name"), row.get("us_per_call")
        if name and isinstance(us, (int, float)) and us > 0:
            rows[name] = float(us)
    return rows, doc


def newest_baseline(repo_root: str, exclude: str):
    cands = [p for p in glob.glob(os.path.join(repo_root, "BENCH_*.json"))
             if os.path.abspath(p) != os.path.abspath(exclude)]
    return max(cands, key=os.path.getmtime) if cands else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json snapshots by row")
    ap.add_argument("fresh", help="the just-produced bench JSON")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="snapshot to compare against (default: newest "
                         "BENCH_*.json in the repo root)")
    ap.add_argument("--tolerance", type=float, default=1.25,
                    help="flag rows slower/faster than this ratio "
                         "(default 1.25)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any row regressed past tolerance")
    args = ap.parse_args(argv)
    if args.tolerance <= 1.0:
        ap.error("--tolerance must be > 1.0")

    baseline = args.baseline or newest_baseline(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        args.fresh)
    if baseline is None:
        print("bench diff: no previous BENCH_*.json to compare against "
              "— skipping")
        return 0
    fresh_rows, fresh_doc = load_rows(args.fresh)
    base_rows, _ = load_rows(baseline)

    shared = sorted(set(fresh_rows) & set(base_rows))
    only_fresh = sorted(set(fresh_rows) - set(base_rows))
    only_base = sorted(set(base_rows) - set(fresh_rows))
    print(f"bench diff: {os.path.basename(args.fresh)} vs "
          f"{os.path.basename(baseline)} "
          f"({len(shared)} shared rows, tolerance {args.tolerance:g}x)")

    regressed = 0
    width = max((len(n) for n in shared), default=4)
    for name in shared:
        b, f = base_rows[name], fresh_rows[name]
        ratio = f / b
        flag = ""
        if ratio > args.tolerance:
            flag = "  REGRESSED"
            regressed += 1
        elif ratio < 1.0 / args.tolerance:
            flag = "  improved"
        print(f"  {name:<{width}}  {b:>12.1f} -> {f:>12.1f} us "
              f"({ratio:>5.2f}x){flag}")
    for name in only_fresh:
        print(f"  {name:<{width}}  (new row: {fresh_rows[name]:.1f} us)")
    for name in only_base:
        print(f"  {name:<{width}}  (row dropped from fresh run)")
    if fresh_doc.get("failures"):
        print(f"  NOTE: fresh run reported failures: "
              f"{fresh_doc['failures']}")

    if regressed:
        print(f"bench diff: {regressed} row(s) past tolerance "
              f"{'(strict: failing)' if args.strict else '(advisory only)'}")
    else:
        print("bench diff: no regressions past tolerance")
    return 1 if (regressed and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
