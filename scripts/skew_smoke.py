"""Skew-adaptive partitioning smoke (CI; DESIGN §12).

End-to-end over the whole skew loop, as a standalone executable
assertion: Zipf-keyed tables land in an adaptive store, the Autopilot's
first tick applies the classic keyed repartition, and the second tick —
under injected calibrations that make padding expensive and shuffles
cheap — must fire a hot-key salt, shrink the padded layout, and keep
every consumer result bit-identical.  A rebucket-only pass (salting
disabled) must do the same through the local capacity-map rewrite.

Usage: python scripts/skew_smoke.py
Exits non-zero on any divergence or missing skew action.
"""

import sys

import numpy as np

from repro.api import Session
from repro.data.partition_store import PartitionStore
from repro.service import (Autopilot, AutopilotConfig, LogicalClock,
                           aggregate_result, drift_tables, q_orderkey)


def scenario(kind: str, **cfg_kw) -> str:
    tables = drift_tables(n_lineitem=6000, skew=1.5)
    store = PartitionStore(num_workers=8)
    for name, data in tables.items():
        store.write(name, data)
    sess = Session(store)
    ap = Autopilot(sess, clock=LogicalClock(),
                   config=AutopilotConfig(min_runs=2.0, hysteresis=0.5,
                                          cooldown_ticks=0,
                                          skew_actions=True, **cfg_kw))
    wl = q_orderkey()
    for _ in range(3):
        sess.run(wl)
    vals, _ = sess.run(wl)
    ref = aggregate_result(vals, wl)

    # calibration sweet spot: shuffles cheap, padding (storage I/O) dear
    ap.cost_model.observe_shuffle(1e9, 0.1)
    ap.cost_model.observe_io(1e6, 1.0)

    ap.tick()                                 # keyed repartition
    ds = store.read("lineitem")
    assert ds.skew() >= 2.0, ds.skew()
    waste = ds.padding_waste()
    assert waste > 0

    rep = ap.tick()                           # the skew action under test
    kinds = {(a.dataset, a.kind) for a in rep.applied}
    assert ("lineitem", kind) in kinds, (kind, kinds)
    ds2 = store.read("lineitem")
    assert ds2.padding_waste() < waste, (ds2.padding_waste(), waste)

    vals2, _ = sess.run(wl)
    got = aggregate_result(vals2, wl)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
    return (f"{kind}: waste {waste} -> {ds2.padding_waste()} bytes, "
            f"skew {ds.skew():.2f} -> {ds2.skew():.2f}, results identical")


def main() -> int:
    print("skew_smoke:", scenario("salt"))
    print("skew_smoke:", scenario("rebucket", hot_key_fraction=2.0))
    print("skew_smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
