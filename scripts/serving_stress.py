"""Time-boxed serving-tier stress smoke (CI; DESIGN §11 + §13).

One shared PartitionStore, CLIENTS concurrent clients hammering a
ServingFrontend while a background thread keeps flipping the scanned
table's layout generation and a background Autopilot ticks on its own
daemon thread.  Every result must be bit-identical to the serial
baseline and nothing may fail — the serial-equivalence guarantee the
serving tier is built on, as a standalone executable assertion.

The whole run is traced (DESIGN §13): at exit it must export one
coherent Chrome-trace JSON — ticket spans parented across the pool
threads, Autopilot ticks on the optimizer thread — plus a metrics
snapshot (JSON + Prometheus text).  Pass an artifacts directory to keep
them (CI uploads these).

Usage: python scripts/serving_stress.py [seconds] [clients] [artifacts_dir]
Exits non-zero on any divergence, error, deadline overrun, or an
incoherent trace.
"""

import json
import os
import sys
import threading
import time

import numpy as np

from repro import obs
from repro.api import Session
from repro.core import Workload, enumerate_candidates
from repro.data.partition_store import PartitionStore
from repro.obs.export import to_chrome_trace
from repro.service import aggregate_result, drift_tables

BUDGET_S = float(sys.argv[1]) if len(sys.argv) > 1 else 20.0
CLIENTS = int(sys.argv[2]) if len(sys.argv) > 2 else 8
ARTIFACTS = sys.argv[3] if len(sys.argv) > 3 else None


def query() -> Workload:
    wl = Workload("stress-q")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    wl.aggregate(j, key=j["odate"], reducer="sum")
    return wl


def _check_trace_coherence(doc) -> list:
    """The §13 acceptance checks on the exported Chrome trace: one
    consistent document whose ticket spans parent across the pool
    boundary and whose Autopilot ticks live on the optimizer thread."""
    problems = []
    ev = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    by_id = {e["args"]["span_id"]: e for e in ev}
    tickets = [e for e in ev if e["name"] == "serve.ticket"]
    ticks = [e for e in ev if e["name"] == "autopilot.tick"]
    threads = {e["tid"]: e["args"]["name"]
               for e in doc["traceEvents"] if e.get("ph") == "M"}
    if not tickets:
        problems.append("no serve.ticket spans in trace")
    cross = 0
    for t in tickets:
        parent = by_id.get(t["args"].get("parent_id"))
        if parent is not None and parent["tid"] != t["tid"]:
            cross += 1
    if not cross:
        problems.append("no ticket span parented across the pool handoff")
    if not ticks:
        problems.append("no autopilot.tick spans in trace")
    elif not all("autopilot" in threads.get(e["tid"], "")
                 for e in ticks):
        problems.append("autopilot.tick span not on the optimizer thread")
    flows = [e for e in doc["traceEvents"] if e.get("ph") in ("s", "f")]
    if len([e for e in flows if e["ph"] == "s"]) != \
            len([e for e in flows if e["ph"] == "f"]):
        problems.append("unpaired flow events")
    return problems


def main() -> int:
    obs.enable("full")
    store = PartitionStore(num_workers=4, backend="host",
                           max_retired_generations=16)
    sess = Session(store)
    for name, data in drift_tables(n_lineitem=3000, n_orders=800,
                                   n_parts=200).items():
        sess.write(name, data)

    want = aggregate_result(sess.run(query()).values, query())
    front = sess.serve(max_workers=CLIENTS, max_queue=4 * CLIENTS)
    ap = sess.autopilot()
    ap.start(period_s=0.5)          # ticks on the lachesis-autopilot thread
    cand = enumerate_candidates(query().graph, "lineitem")[0]
    deadline = time.perf_counter() + BUDGET_S
    stop = threading.Event()
    flips = [0]
    errors = []

    def flipper():
        while not stop.is_set():
            store.repartition(store.read("lineitem"), cand, swap=True)
            flips[0] += 1

    def client(cid):
        try:
            while time.perf_counter() < deadline:
                res = front.run(query(), coalesce=bool(cid % 2),
                                timeout=120, block=True)
                got = aggregate_result(res.values, query())
                for k in want:
                    np.testing.assert_array_equal(got[k], want[k])
        except BaseException as e:      # noqa: BLE001
            errors.append((cid, repr(e)))

    ft = threading.Thread(target=flipper, daemon=True)
    ft.start()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=BUDGET_S + 120)
    stop.set()
    ft.join(60)
    ap.stop()
    stuck = [t for t in threads if t.is_alive()]
    st = front.stats()
    metrics_text = front.metrics_text()
    front.close(wait=not stuck)

    # -- observability artifacts (DESIGN §13) -------------------------------
    doc = sess.export_trace()
    trace_problems = _check_trace_coherence(doc)
    if ARTIFACTS:
        os.makedirs(ARTIFACTS, exist_ok=True)
        with open(os.path.join(ARTIFACTS, "stress_trace.json"), "w") as f:
            json.dump(doc, f)
        sess.metrics_registry.write_snapshot(
            os.path.join(ARTIFACTS, "stress_metrics.json"))
        with open(os.path.join(ARTIFACTS, "stress_metrics.prom"), "w") as f:
            f.write(metrics_text)
    n_spans = doc["otherData"]["spans"]
    ticks = len(ap.optimizer.reports)

    print(f"serving_stress: clients={CLIENTS} budget={BUDGET_S}s "
          f"completed={st['completed']} coalesced={st['coalesced']} "
          f"flips={flips[0]} failed={st['failed']} "
          f"autopilot_ticks={ticks} trace_spans={n_spans} "
          f"dropped={doc['otherData']['dropped']}")
    if errors:
        print(f"FAIL: {len(errors)} clients diverged/errored: {errors[:3]}")
        return 1
    if stuck:
        print(f"FAIL: {len(stuck)} clients deadlocked past the deadline")
        return 1
    if st["failed"] or st["completed"] < CLIENTS:
        print("FAIL: serving counters show failures or vacuous coverage")
        return 1
    if flips[0] < 2:
        print("FAIL: background flipper never ran — stress was vacuous")
        return 1
    if ap.optimizer.last_error is not None:
        print(f"FAIL: autopilot thread died: {ap.optimizer.last_error!r}")
        return 1
    if trace_problems:
        print(f"FAIL: trace incoherent: {trace_problems}")
        return 1
    if "serving_completed" not in metrics_text or \
            "serving_latency_seconds_bucket" not in metrics_text:
        print("FAIL: metrics exposition missing serving series")
        return 1
    print("OK: bit-identical under concurrency + background repartition; "
          "trace + metrics exported coherently")
    return 0


if __name__ == "__main__":
    sys.exit(main())
