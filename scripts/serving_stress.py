"""Time-boxed serving-tier stress smoke (CI; DESIGN §11).

One shared PartitionStore, CLIENTS concurrent clients hammering a
ServingFrontend while a background thread keeps flipping the scanned
table's layout generation.  Every result must be bit-identical to the
serial baseline and nothing may fail — the serial-equivalence guarantee
the serving tier is built on, as a standalone executable assertion.

Usage: python scripts/serving_stress.py [seconds] [clients]
Exits non-zero on any divergence, error or deadline overrun.
"""

import sys
import threading
import time

import numpy as np

from repro.api import Session
from repro.core import Workload, enumerate_candidates
from repro.data.partition_store import PartitionStore
from repro.service import aggregate_result, drift_tables

BUDGET_S = float(sys.argv[1]) if len(sys.argv) > 1 else 20.0
CLIENTS = int(sys.argv[2]) if len(sys.argv) > 2 else 8


def query() -> Workload:
    wl = Workload("stress-q")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    wl.aggregate(j, key=j["odate"], reducer="sum")
    return wl


def main() -> int:
    store = PartitionStore(num_workers=4, backend="host",
                           max_retired_generations=16)
    sess = Session(store)
    for name, data in drift_tables(n_lineitem=3000, n_orders=800,
                                   n_parts=200).items():
        sess.write(name, data)

    want = aggregate_result(sess.run(query()).values, query())
    front = sess.serve(max_workers=CLIENTS, max_queue=4 * CLIENTS)
    cand = enumerate_candidates(query().graph, "lineitem")[0]
    deadline = time.perf_counter() + BUDGET_S
    stop = threading.Event()
    flips = [0]
    errors = []

    def flipper():
        while not stop.is_set():
            store.repartition(store.read("lineitem"), cand, swap=True)
            flips[0] += 1

    def client(cid):
        try:
            while time.perf_counter() < deadline:
                res = front.run(query(), coalesce=bool(cid % 2),
                                timeout=120, block=True)
                got = aggregate_result(res.values, query())
                for k in want:
                    np.testing.assert_array_equal(got[k], want[k])
        except BaseException as e:      # noqa: BLE001
            errors.append((cid, repr(e)))

    ft = threading.Thread(target=flipper, daemon=True)
    ft.start()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=BUDGET_S + 120)
    stop.set()
    ft.join(60)
    stuck = [t for t in threads if t.is_alive()]
    st = front.stats()
    front.close(wait=not stuck)

    print(f"serving_stress: clients={CLIENTS} budget={BUDGET_S}s "
          f"completed={st['completed']} coalesced={st['coalesced']} "
          f"flips={flips[0]} failed={st['failed']}")
    if errors:
        print(f"FAIL: {len(errors)} clients diverged/errored: {errors[:3]}")
        return 1
    if stuck:
        print(f"FAIL: {len(stuck)} clients deadlocked past the deadline")
        return 1
    if st["failed"] or st["completed"] < CLIENTS:
        print("FAIL: serving counters show failures or vacuous coverage")
        return 1
    if flips[0] < 2:
        print("FAIL: background flipper never ran — stress was vacuous")
        return 1
    print("OK: bit-identical under concurrency + background repartition")
    return 0


if __name__ == "__main__":
    sys.exit(main())
