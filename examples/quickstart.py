"""Quickstart: the Lachesis loop in 60 lines, via ``lachesis.Session``.

1. Trace two workloads (a loader and a join) in the DSL.
2. Log historical executions; the advisor (Alg. 3) extracts partitioner
   candidates from the consumer IR and picks one.
3. Store data with the chosen persistent partitioning.
4. ``session.explain`` shows the compiled PhysicalPlan: both shuffles are
   statically elided (Alg. 4 at plan time); ``session.run`` executes it,
   and a second run is a pure plan-cache hit.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import lachesis
from repro.core import (HistoryStore, author_integrator,
                        enumerate_candidates, partitioning_creation)
from repro.core.dsl import reddit_loader

# -- 1. workloads ------------------------------------------------------------
loader = reddit_loader("submission-loader", "raw", "submissions", "json")
consumer = author_integrator()          # joins submissions ⋈ authors

# -- 2. history + advisor -------------------------------------------------------
cand = enumerate_candidates(consumer.graph, "submissions")[0]
print("extracted candidate:", cand.signature())      # Listing 2 from Listing 1

history = HistoryStore()
for t in range(2):                      # two past runs of the workflow
    history.log_workload(loader, timestamp=100.0 * t, latency=30.0,
                         input_bytes=2e9)
    history.log_workload(consumer, timestamp=100.0 * t + 50, latency=90.0,
                         input_bytes=3e9,
                         candidate_stats={cand.signature(): {
                             "selectivity": 0.1, "distinct_keys": 1e6}})

decision = partitioning_creation(loader, "submissions", history,
                                 dataset_bytes=2e9)
print("advisor picked:", decision.candidate.strategy,
      decision.candidate.signature())

# -- 3. storage-time partitioning ------------------------------------------------
rng = np.random.default_rng(0)
subs = {"author": rng.integers(0, 1000, 20_000), "score": rng.normal(size=20_000)}
auths = {"author": np.arange(1000), "karma": rng.normal(size=1000)}

session = lachesis.Session(num_workers=8)
session.write("submissions", subs, decision.candidate)
session.write("authors", auths,
              enumerate_candidates(consumer.graph, "authors")[0])

# -- 4. plan, then execute shuffle-free --------------------------------------------
print(session.explain(consumer))        # both partition nodes: ELIDED
result = session.run(consumer)
stats = result.stats
print(f"join ran with {stats.shuffles_performed} shuffles "
      f"({stats.shuffles_elided} elided, {stats.shuffle_bytes} bytes moved)")
assert stats.shuffles_performed == 0
rerun = session.run(consumer)           # same workload, same layout ⇒ hit
assert rerun.stats.plan_cache_hit
print("OK — persistent partitioning made the join local; re-run was a "
      f"pure plan-cache hit ({session.plan_cache_stats()}).")

# -- 5. the device backend (DESIGN §5/§9) ------------------------------------------
# With a round-robin store the shuffles are real; backend="device" binds
# the partition nodes to the cached single-pass ShufflePlans (Pallas
# kernels on TPU, interpret mode off-TPU), bit-identical to the host path.
dev = lachesis.Session(num_workers=8, backend="device")
dev.write("submissions", subs)
dev.write("authors", auths)
dev_stats = dev.run(consumer).stats
assert dev_stats.device_repartitions == dev_stats.shuffles_performed == 2
print(f"device backend: {dev_stats.device_repartitions} repartitions ran "
      "through the ShufflePlan path.")
