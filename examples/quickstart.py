"""Quickstart: the Lachesis loop in 60 lines.

1. Trace two workloads (a loader and a join) in the DSL.
2. Log historical executions; the advisor (Alg. 3) extracts partitioner
   candidates from the consumer IR and picks one.
3. Store data with the chosen persistent partitioning.
4. Run the consumer: the matcher (Alg. 4) elides both shuffles.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (Engine, HistoryStore, author_integrator,
                        enumerate_candidates, partitioning_creation)
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore

# -- 1. workloads ------------------------------------------------------------
loader = reddit_loader("submission-loader", "raw", "submissions", "json")
consumer = author_integrator()          # joins submissions ⋈ authors

# -- 2. history + advisor -------------------------------------------------------
cand = enumerate_candidates(consumer.graph, "submissions")[0]
print("extracted candidate:", cand.signature())      # Listing 2 from Listing 1

history = HistoryStore()
for t in range(2):                      # two past runs of the workflow
    history.log_workload(loader, timestamp=100.0 * t, latency=30.0,
                         input_bytes=2e9)
    history.log_workload(consumer, timestamp=100.0 * t + 50, latency=90.0,
                         input_bytes=3e9,
                         candidate_stats={cand.signature(): {
                             "selectivity": 0.1, "distinct_keys": 1e6}})

decision = partitioning_creation(loader, "submissions", history,
                                 dataset_bytes=2e9)
print("advisor picked:", decision.candidate.strategy,
      decision.candidate.signature())

# -- 3. storage-time partitioning ------------------------------------------------
rng = np.random.default_rng(0)
subs = {"author": rng.integers(0, 1000, 20_000), "score": rng.normal(size=20_000)}
auths = {"author": np.arange(1000), "karma": rng.normal(size=1000)}

store = PartitionStore(num_workers=8)
store.write("submissions", subs, decision.candidate)
store.write("authors", auths,
            enumerate_candidates(consumer.graph, "authors")[0])

# -- 4. shuffle-free execution -----------------------------------------------------
vals, stats = Engine(store).run(consumer)
print(f"join ran with {stats.shuffles_performed} shuffles "
      f"({stats.shuffles_elided} elided, {stats.shuffle_bytes} bytes moved)")
assert stats.shuffles_performed == 0
print("OK — persistent partitioning made the join local.")

# -- 5. the device repartition path (DESIGN §5) ------------------------------------
# With a round-robin store the shuffles are real; backend="device" routes
# them through the Pallas hash-partition kernel (interpret mode off-TPU),
# bit-identical to the host path.
rr_store = PartitionStore(num_workers=8)
rr_store.write("submissions", subs)
rr_store.write("authors", auths)
_, dev_stats = Engine(rr_store, backend="device").run(consumer)
assert dev_stats.device_repartitions == dev_stats.shuffles_performed == 2
print(f"device backend: {dev_stats.device_repartitions} repartitions ran "
      "through the Pallas kernel.")
