"""Autopilot drift demo: the service closes the loop on a shifting mix.

TPC-H-like tables start round-robin.  An orderkey-join query (Q04 family)
runs a few times; the Autopilot observes every run, decides lineitem and
orders should live hash-partitioned on orderkey, repartitions them in
place (a new generation, atomically swapped), and the next run's join
shuffles are elided.  Then the mix *drifts* to a partkey join (Q17
family): the orderkey traffic ages out of the recency window and the
service re-partitions lineitem onto partkey — all deterministically via
``tick()`` with a logical clock.

Run:  PYTHONPATH=src python examples/autopilot_drift.py
      PYTHONPATH=src python examples/autopilot_drift.py device   # d2d path
"""

import sys

import numpy as np

from repro.service import run_drift_scenario

backend = sys.argv[1] if len(sys.argv) > 1 else "host"
rep = run_drift_scenario(backend=backend)


def show(tag, runs):
    for r in runs:
        print(f"  {tag}: shuffles={r.shuffles} elided={r.elided} "
              f"bytes={r.shuffle_bytes} wall={r.wall_s * 1e3:.1f}ms")


def show_tick(tag, tick):
    if not tick.applied:
        print(f"  {tag}: no action (cooldown / below hysteresis)")
    for a in tick.applied:
        s = a.score
        print(f"  {tag}: {a.dataset} -> {a.decision.candidate.signature()} "
              f"gen={a.generation} path={a.path} "
              f"benefit={s.benefit_s * 1e3:.1f}ms/window "
              f"cost={s.repartition_s * 1e3:.1f}ms")


print(f"== phase A: orderkey mix (backend={backend}, round-robin layout)")
show("run", rep.phase_a)
print("== tick: observe -> decide -> repartition -> swap generation")
show_tick("decision", rep.tick_a)
print("== post-decision run (join shuffles elided)")
show("run", [rep.post_a])

print("== phase B: mix drifts to partkey joins")
show("run", rep.phase_b)
show_tick("early tick", rep.tick_b_mid)
show_tick("drift tick", rep.tick_b)
print("== post-drift run")
show("run", [rep.post_b])

print("== lineitem layout trajectory")
for g, p in zip(rep.lineitem_generations, rep.lineitem_partitioners):
    print(f"  generation {g}: {p}")

for k in rep.result_pre_a:
    np.testing.assert_array_equal(rep.result_pre_a[k], rep.result_post_a[k])
for k in rep.result_pre_b:
    np.testing.assert_array_equal(rep.result_pre_b[k], rep.result_post_b[k])
print("query results bit-identical across all layout generations ✓")
