"""Train a ~100M-param LM end to end on CPU, with a mid-run injected node
failure and automatic checkpoint restart (exactly-once data replay).

The arch is the assigned mamba2-370m family at reduced width (~2M params for
CPU speed; pass --full-370m to train the real config if you have the time
budget — same code path).

Run:  PYTHONPATH=src python examples/train_lm.py
"""

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.reduced import reduced
from repro.launch.train import TrainRun, train_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-370m", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_config("mamba2-370m")
    if not args.full_370m:
        cfg = reduced(cfg)
        cfg = dataclasses.replace(cfg, num_layers=4)
    ckpt = tempfile.mkdtemp(prefix="lachesis_ckpt_")
    run = TrainRun(cfg=cfg, total_steps=args.steps, global_batch=8,
                   seq_len=256, ckpt_dir=ckpt, ckpt_every=25,
                   peak_lr=1e-3, fail_at_step=args.steps // 2)
    out = train_with_restarts(run)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\nloss {first:.3f} → {last:.3f} across an injected failure at "
          f"step {args.steps // 2} (restart from {ckpt})")
    assert last < first, "training must make progress through the restart"


if __name__ == "__main__":
    main()
