"""End-to-end Reddit data-integration scenario (paper §5.2.1, Fig. 5).

Compares w/o Lachesis (round-robin storage, shuffling join) against
w/ Lachesis (advisor-partitioned storage, local join), reporting the
speedup, shuffle bytes avoided, and producer-side overhead (Tab. 3).

Run:  PYTHONPATH=src python examples/reddit_integration.py
"""

import sys

sys.path.insert(0, "benchmarks")

from benchmarks.bench_reddit import run_case   # noqa: E402

if __name__ == "__main__":
    print("name,us_per_call,derived")
    sw, sm = run_case("small", 200_000, 50_000)
    sw2, sm2 = run_case("large", 1_200_000, 300_000)
    print(f"\nSpeedups — small: {sw:.2f}x wall ({sm:.2f}x modeled at "
          f"10 Gbps); large: {sw2:.2f}x wall ({sm2:.2f}x modeled).")
    print("Paper (real 10-node cluster): 4.8x small, 14.7x large — the gap "
          "is the single-host substrate; shuffles 2→0 matches exactly.")
