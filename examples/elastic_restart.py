"""Elastic restart: lose a node mid-run, re-mesh, reshard, resume.

Control-plane walkthrough on CPU (the data plane is proven by the dry-run):
  1. a Coordinator detects a dead worker from missed heartbeats;
  2. `replan_mesh` shrinks the data axis to the surviving chip count while
     preserving the model axis (TP layout is layout-critical);
  3. `resharding_plan` emits the deterministic old-shard → new-shard map;
  4. training resumes from the latest checkpoint with the new plan, and the
     seekable TokenSource replays the batch stream exactly-once.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import dataclasses
import tempfile

import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduced
from repro.launch.train import TrainRun, train
from repro.runtime.elastic import MeshPlan, replan_mesh, resharding_plan
from repro.runtime.fault_tolerance import Coordinator, RunState, WorkerFailure


def main():
    # --- failure detection -------------------------------------------------
    coord = Coordinator(num_workers=256, miss_threshold=2)
    for step in (1, 2):
        for w in range(256):
            if w != 137:                      # chip 137 dies silently
                coord.heartbeat(w, step)
        ev = coord.tick(step, checkpoint_step=100)
    assert ev and ev.worker == 137 and coord.state == RunState.RECOVERING
    print(f"[elastic] worker {ev.worker} declared dead at step {ev.step}; "
          f"restart from checkpoint step {ev.restart_step}")

    # --- re-mesh ------------------------------------------------------------
    old = MeshPlan((16, 16), ("data", "model"))
    new = replan_mesh(old, surviving_devices=255)
    print(f"[elastic] mesh {old.shape} -> {new.shape} "
          f"({new.num_devices} chips; model axis preserved)")
    plan = resharding_plan(old, new, batch_dim=256)
    print(f"[elastic] per-device batch {256 // 16} -> "
          f"{plan['per_device_batch']}; first assignment: "
          f"{plan['assignments'][0]}")

    # --- resume training (CPU-scale model, same code path) -------------------
    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b")),
                              num_layers=2)
    ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")
    run = TrainRun(cfg=cfg, total_steps=30, global_batch=8, seq_len=64,
                   ckpt_dir=ckpt, ckpt_every=10, peak_lr=1e-3)
    try:
        train(dataclasses.replace(run, fail_at_step=15))
    except WorkerFailure as e:
        print(f"[elastic] {e} — resuming on the shrunken mesh")
    out = train(run)                          # restores from step 10
    assert out["final_step"] == 30
    assert np.isfinite(out["losses"]).all()
    coord.recover()
    print(f"[elastic] resumed and finished: loss "
          f"{out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}; "
          f"coordinator state = {coord.state.value}")


if __name__ == "__main__":
    main()
