"""Batched serving: prefill a prompt batch, decode tokens with a KV cache.

Uses the assigned internlm2-1.8b family at reduced width; the same
`repro.launch.serve` driver lowers the full config in the dry-run.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduced
from repro.launch.serve import serve_batch
from repro.models import transformer as T


def main():
    cfg = reduced(get_config("internlm2-1.8b"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    prompts = np.asarray(jax.random.randint(key, (4, 64), 0,
                                            cfg.vocab_size), np.int32)
    gen, stats = serve_batch(cfg, params, prompts, gen_tokens=32)
    print(f"generated {gen.shape[1]} tokens for {gen.shape[0]} requests: "
          f"prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tokens_per_s']:.1f} tok/s decode")
    assert np.isfinite(gen).all() and gen.shape == (4, 32)


if __name__ == "__main__":
    main()
