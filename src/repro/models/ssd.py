"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within a chunk the recurrence is computed as a
masked attention-like quadratic form (the "duality"); across chunks a
linear scan carries the (H, P, N) state.  This is the TPU-native layout:
chunk matmuls hit the MXU, the cross-chunk scan is O(T/chunk) sequential.

Decode maintains the recurrent state directly:  h ← dA·h + dt·B⊗x,
y = C·h + D·x  — no KV cache at all (the long_500k story for this arch).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, dense_init, rmsnorm, rmsnorm_init


def ssd_init(key, d_model: int, *, d_inner: int, state: int, nheads: int,
             conv_width: int, dtype) -> Params:
    ks = jax.random.split(key, 5)
    headdim = d_inner // nheads
    d_in_proj = 2 * d_inner + 2 * state + nheads   # z, x, B, C, dt
    p = {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, d_inner + 2 * state),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * state,), dtype),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype),
    }
    return p


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,T,C); w: (W,C) depthwise causal conv."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _segsum(log_a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: L[..., i, j] = Σ_{j<k≤i} log_a[k]."""
    T = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, chunk: int,
                 init_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: (B,T,H,P); dt: (B,T,H); A: (H,) (negative);
    Bm/Cm: (B,T,N).  Returns (y: (B,T,H,P), final_state: (B,H,P,N)).

    One ``lax.scan`` over chunks: only a single chunk's (H, L, L) decay tile
    is live at a time (matches the Pallas kernel's VMEM footprint; the
    all-chunks-at-once formulation needs O(T·L) memory and OOMs at 4k+)."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    nc = T // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)

    h0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def body(h, inp):
        xi, dti, bi, ci = inp          # (B,L,H,P),(B,L,H),(B,L,N),(B,L,N)
        dti = dti.astype(jnp.float32)
        dA = dti * A                                              # (B,L,H)
        cs = jnp.cumsum(dA, axis=1)
        # intra-chunk quadratic form
        Lm = jnp.exp(_segsum(dA.transpose(0, 2, 1)))              # (B,H,L,L)
        scores = jnp.einsum("bln,bmn->blm", ci.astype(jnp.float32),
                            bi.astype(jnp.float32))
        y = jnp.einsum("blm,bhlm,bmh,bmhp->blhp", scores, Lm, dti,
                       xi.astype(jnp.float32))
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bln,blh,bhpn->blhp", ci.astype(jnp.float32),
                           jnp.exp(cs), h)
        # state update
        decay_states = jnp.exp(cs[:, -1:, :] - cs) * dti          # (B,L,H)
        upd = jnp.einsum("bln,blh,blhp->bhpn", bi.astype(jnp.float32),
                         decay_states, xi.astype(jnp.float32))
        h = h * jnp.exp(cs[:, -1])[..., None, None] + upd
        return h, y.astype(x.dtype)

    h_final, ys = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, P)
    return y, h_final.astype(x.dtype)


def ssd_block(p: Params, x: jax.Array, *, d_inner: int, state: int,
              nheads: int, chunk: int,
              rec_state: Optional[Dict[str, jax.Array]] = None,
              return_final_state: bool = False
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full Mamba-2 mixer.  x: (B,T,D).

    Training: rec_state=None, chunked scan over T.
    Prefill:  rec_state=None, return_final_state=True → returns decode state.
    Decode: rec_state = {"h": (B,H,P,N), "conv": (B,W-1,Cconv)}; T must be 1.
    """
    B, T, D = x.shape
    P = d_inner // nheads
    zxbcdt = dense(p["in_proj"], x)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + state,
                 2 * d_inner + 2 * state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    if rec_state is None:
        conv_out = _causal_conv1d(conv_in, p["conv_w"], p["conv_b"])
        new_state = None
    else:
        W = p["conv_w"].shape[0]
        hist = jnp.concatenate([rec_state["conv"], conv_in], axis=1)
        conv_out = sum(hist[:, i:i + T] * p["conv_w"][i] for i in range(W))
        conv_out = jax.nn.silu(conv_out + p["conv_b"])
        new_conv = hist[:, -(W - 1):]
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + state], axis=-1)
    xh = xin.reshape(B, T, nheads, P)

    if rec_state is None:
        # pad T to a chunk multiple; padded steps have dt=0 ⇒ no state change
        T_pad = -(-T // chunk) * chunk
        if T_pad != T:
            pad = ((0, 0), (0, T_pad - T))
            xh = jnp.pad(xh, pad + ((0, 0), (0, 0)))
            dt = jnp.pad(dt, pad + ((0, 0),))
            Bm = jnp.pad(Bm, pad + ((0, 0),))
            Cm = jnp.pad(Cm, pad + ((0, 0),))
        y, final = ssd_scan_ref(xh, dt, A, Bm, Cm, chunk)
        y, xh = y[:, :T], xh[:, :T]
        if return_final_state:
            W = p["conv_w"].shape[0]
            new_state = {"h": final,
                         "conv": conv_in[:, -(W - 1):].astype(conv_in.dtype)}
    else:
        # single-token recurrent update
        dA = jnp.exp(dt[:, 0] * A)                                # (B,H)
        h = rec_state["h"].astype(jnp.float32)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32))
        h = h * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y[:, None].reshape(B, 1, nheads, P).astype(x.dtype)
        new_state = {"h": h.astype(rec_state["h"].dtype), "conv": new_conv}

    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B, T, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return dense(p["out_proj"], y), new_state


def ssd_state_shape(B: int, d_inner: int, state: int, nheads: int,
                    conv_width: int, dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    P = d_inner // nheads
    return {"h": jax.ShapeDtypeStruct((B, nheads, P, state), dtype),
            "conv": jax.ShapeDtypeStruct((B, conv_width - 1,
                                          d_inner + 2 * state), dtype)}
