"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV are compressed into a low-rank latent ``c_kv`` (kv_lora_rank) plus a
shared RoPE key slice; the decode cache stores only (c_kv ‖ k_rope) —
(kv_lora_rank + rope_head_dim) floats per token instead of
2·H·head_dim.  For the 500k-context shapes this is the difference between
a multi-TB and tens-of-GB cache, i.e. the "persistent partitioning" of the
cache becomes feasible at all.

Shapes follow the paper: per-head dims (nope=128, rope=64, v=128); queries
optionally low-rank too (q_lora_rank).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (Params, apply_rope, dense, dense_init, rmsnorm,
                     rmsnorm_init)


def mla_init(key, d_model: int, num_heads: int, *, kv_lora_rank: int,
             q_lora_rank: int, nope_head_dim: int, rope_head_dim: int,
             v_head_dim: int, dtype) -> Params:
    ks = jax.random.split(key, 8)
    H = num_heads
    p: Params = {
        # queries: d_model -> q_lora -> H*(nope+rope)
        "wq_a": dense_init(ks[0], d_model, q_lora_rank, dtype),
        "q_norm": rmsnorm_init(q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], q_lora_rank,
                           H * (nope_head_dim + rope_head_dim), dtype),
        # kv: d_model -> (kv_lora + rope) ; latent -> H*(nope + v)
        "wkv_a": dense_init(ks[2], d_model, kv_lora_rank + rope_head_dim, dtype),
        "kv_norm": rmsnorm_init(kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[3], kv_lora_rank,
                            H * (nope_head_dim + v_head_dim), dtype),
        "wo": dense_init(ks[4], H * v_head_dim, d_model, dtype),
    }
    return p


def _project_q(p, x, H, nd, rd, positions, rope_theta):
    q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    q = q.reshape(x.shape[:-1] + (H, nd + rd))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, kv_lora, rd, positions, rope_theta):
    kv = dense(p["wkv_a"], x)                                    # (...,S,R+rd)
    c_kv = rmsnorm(p["kv_norm"], kv[..., :kv_lora])
    k_rope = kv[..., None, kv_lora:]                             # (...,S,1,rd)
    k_rope = apply_rope(k_rope, positions, rope_theta)
    return c_kv, k_rope[..., 0, :]


def _expand_kv(p, c_kv, H, nd, vd):
    kvb = dense(p["wkv_b"], c_kv).reshape(c_kv.shape[:-1] + (H, nd + vd))
    return kvb[..., :nd], kvb[..., nd:]                          # k_nope, v


def mla_attention(p: Params, x: jax.Array, *, num_heads: int,
                  kv_lora_rank: int, nope_head_dim: int, rope_head_dim: int,
                  v_head_dim: int, rope_theta: float, positions: jax.Array,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  cache_pos: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,S,D).  cache = {"ckv": (B,L,R), "krope": (B,L,rd)}."""
    B, S, D = x.shape
    H, nd, rd, vd, R = (num_heads, nope_head_dim, rope_head_dim,
                        v_head_dim, kv_lora_rank)
    scale = 1.0 / math.sqrt(nd + rd)

    q_nope, q_rope = _project_q(p, x, H, nd, rd, positions, rope_theta)
    c_kv, k_rope = _project_kv_latent(p, x, R, rd, positions, rope_theta)

    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        krope = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype),
            cache_pos, axis=1)
        new_cache = {"ckv": ckv, "krope": krope}
        c_kv, k_rope = ckv, krope
        kv_len = cache_pos + S
        q_offset = cache_pos
    else:
        kv_len = None
        q_offset = 0

    k_nope, v = _expand_kv(p, c_kv, H, nd, vd)                   # (B,Skv,H,·)

    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[..., None, :],
                                  k_nope.shape[:-1] + (rd,))], -1)
    from .layers import auto_sdpa                   # blockwise for long S
    out = auto_sdpa(q_full, k_full, v, causal=True, q_offset=q_offset,
                    kv_len=kv_len, scale=scale)     # (B,S,H,vd)
    y = dense(p["wo"], out.reshape(B, S, H * vd).astype(x.dtype))
    return y, new_cache


def mla_cache_shape(B: int, L: int, kv_lora_rank: int, rope_head_dim: int,
                    dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    return {"ckv": jax.ShapeDtypeStruct((B, L, kv_lora_rank), dtype),
            "krope": jax.ShapeDtypeStruct((B, L, rope_head_dim), dtype)}


def mla_attention_absorbed(p: Params, x: jax.Array, *, num_heads: int,
                           kv_lora_rank: int, nope_head_dim: int,
                           rope_head_dim: int, v_head_dim: int,
                           rope_theta: float, positions: jax.Array,
                           cache: Dict[str, jax.Array],
                           cache_pos) -> Tuple[jax.Array, Dict]:
    """Weight-absorbed MLA decode (beyond-paper perf variant).

    Scores are computed against the *latent* cache directly:
        q_abs = q_nope · W_uk          (B,S,H,R)
        s     = q_abs · c_kvᵀ + q_rope · k_ropeᵀ
        o     = (softmax(s) · c_kv) · W_uv
    No (B,L,H,·) K/V expansion ⇒ cache-side HBM traffic drops from
    H·(nd+vd) to R+rd per cached token — the §Perf hillclimb for the MLA
    decode cells."""
    B, S, D = x.shape
    H, nd, rd, vd, R = (num_heads, nope_head_dim, rope_head_dim,
                        v_head_dim, kv_lora_rank)
    scale = 1.0 / math.sqrt(nd + rd)

    q_nope, q_rope = _project_q(p, x, H, nd, rd, positions, rope_theta)
    c_kv_new, k_rope_new = _project_kv_latent(p, x, R, rd, positions,
                                              rope_theta)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_kv_new.astype(cache["ckv"].dtype), cache_pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], k_rope_new.astype(cache["krope"].dtype),
        cache_pos, axis=1)
    new_cache = {"ckv": ckv, "krope": krope}

    wkv_b = p["wkv_b"]["w"].reshape(R, H, nd + vd)
    w_uk = wkv_b[..., :nd]                                  # (R,H,nd)
    w_uv = wkv_b[..., nd:]                                  # (R,H,vd)

    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_abs,
                        ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        krope.astype(jnp.float32))
    s = (s_nope + s_rope) * scale

    Skv = ckv.shape[1]
    k_pos = jnp.arange(Skv)[None, :]
    q_pos = jnp.arange(S)[:, None] + cache_pos
    mask = (k_pos <= q_pos) & (k_pos < cache_pos + S)
    s = jnp.where(mask[None, None], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    o_latent = jnp.einsum("bhqk,bkr->bqhr", probs, ckv.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", o_latent, w_uv.astype(jnp.float32))
    y = dense(p["wo"], out.reshape(B, S, H * vd).astype(x.dtype))
    return y, new_cache
