"""Config-driven model: one implementation covering all 10 architectures.

The layer stack is a ``lax.scan`` over pattern groups (compile time flat in
depth), with optional unrolled prefix/tail layers.  Three modes share the
layer dispatcher:

* ``train``   — full-sequence forward, no caches, remat over groups
* ``prefill`` — full-sequence forward that also *emits* the decode cache
* ``decode``  — single-token step updating the cache in place

Cache kinds per mixer: attention → KV (optionally ring-buffered for local
layers), MLA → compressed latent, SSD/RG-LRU → recurrent state.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import rglru as RG
from . import ssd as SSD

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# Init
# ===========================================================================

def _init_layer(cfg: ArchConfig, spec: LayerSpec, key,
                cross_attention: bool = False) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    p: Params = {}
    if spec.mixer == "attn":
        p["ln_attn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["attn"] = L.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, dt,
                                     qkv_bias=cfg.qkv_bias,
                                     qk_norm=cfg.qk_norm)
        if cfg.use_post_norm:
            p["ln_attn_post"] = L.norm_init(cfg.norm, cfg.d_model, dt)
    elif spec.mixer == "mla":
        m = cfg.mla
        p["ln_attn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["attn"] = MLA.mla_init(ks[0], cfg.d_model, cfg.num_heads,
                                 kv_lora_rank=m.kv_lora_rank,
                                 q_lora_rank=m.q_lora_rank,
                                 nope_head_dim=m.nope_head_dim,
                                 rope_head_dim=m.rope_head_dim,
                                 v_head_dim=m.v_head_dim, dtype=dt)
    elif spec.mixer == "ssd":
        s = cfg.ssd
        p["ln_attn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["attn"] = SSD.ssd_init(ks[0], cfg.d_model, d_inner=s.d_inner,
                                 state=s.state, nheads=s.nheads,
                                 conv_width=s.conv_width, dtype=dt)
    elif spec.mixer == "rglru":
        r = cfg.rglru
        p["ln_attn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["attn"] = RG.rglru_init(ks[0], cfg.d_model, width=r.width,
                                  conv_width=r.conv_width, dtype=dt)

    if cross_attention:
        p["ln_cross"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["cross"] = L.attention_init(ks[1], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.head_dim, dt)

    if spec.ffn == "dense":
        p["ln_ffn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["ffn"] = L.ffn_init(ks[2], cfg.d_model, cfg.d_ff, dt,
                              gated=cfg.ffn_gated)
        if cfg.use_post_norm:
            p["ln_ffn_post"] = L.norm_init(cfg.norm, cfg.d_model, dt)
    elif spec.ffn == "moe":
        m = cfg.moe
        p["ln_ffn"] = L.norm_init(cfg.norm, cfg.d_model, dt)
        p["ffn"] = MOE.moe_init(ks[2], cfg.d_model, m.d_ff_expert,
                                m.num_experts, m.num_shared, dt)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": L.norm_init(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(keys[1], cfg.padded_vocab,
                                         cfg.d_model, dt)
    if cfg.positional == "learned":
        params["pos_embed"] = (jax.random.normal(
            keys[2], (cfg.max_learned_pos, cfg.d_model), jnp.float32)
            * 0.01).astype(dt)

    cross = cfg.encoder is not None
    # scanned groups: per-slot params stacked over G
    G = cfg.pattern_groups
    blocks: Params = {}
    for s, spec in enumerate(cfg.pattern):
        slot_keys = jax.random.split(jax.random.fold_in(keys[3], s), G)
        blocks[f"s{s}"] = jax.vmap(
            lambda k: _init_layer(cfg, spec, k, cross))(slot_keys)
    params["blocks"] = blocks
    for i, spec in enumerate(cfg.prefix):
        params[f"prefix{i}"] = _init_layer(cfg, spec,
                                           jax.random.fold_in(keys[4], i),
                                           cross)
    for i, spec in enumerate(cfg.tail_specs):
        params[f"tail{i}"] = _init_layer(cfg, spec,
                                         jax.random.fold_in(keys[5], i),
                                         cross)
    if cfg.encoder is not None:
        e = cfg.encoder
        enc_spec = LayerSpec(mixer="attn", attn_kind="global",
                             use_rope=False, ffn="dense")
        enc_keys = jax.random.split(keys[6], e.num_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _init_layer(cfg, enc_spec, k, False))(enc_keys),
            "norm": L.norm_init(cfg.norm, cfg.d_model, dt),
        }
    return params


# ===========================================================================
# Cache init
# ===========================================================================

def _layer_cache_shape(cfg: ArchConfig, spec: LayerSpec, B: int, Lc: int,
                       dtype) -> Optional[Dict]:
    if spec.mixer == "attn":
        length = Lc
        if spec.attn_kind == "local" and cfg.windowed_local_cache:
            length = min(Lc, cfg.sliding_window)
        kv = (B, length, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    if spec.mixer == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((B, Lc, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((B, Lc, m.rope_head_dim), dtype)}
    if spec.mixer == "ssd":
        s = cfg.ssd
        P = s.d_inner // s.nheads
        return {"h": jnp.zeros((B, s.nheads, P, s.state), dtype),
                "conv": jnp.zeros((B, s.conv_width - 1,
                                   s.d_inner + 2 * s.state), dtype)}
    if spec.mixer == "rglru":
        r = cfg.rglru
        return {"h": jnp.zeros((B, r.width), dtype),
                "conv": jnp.zeros((B, r.conv_width - 1, r.width), dtype)}
    return None


def init_cache(cfg: ArchConfig, B: int, Lc: int) -> Params:
    dt = _dtype(cfg)
    G = cfg.pattern_groups
    cache: Params = {"blocks": {}}
    for s, spec in enumerate(cfg.pattern):
        one = _layer_cache_shape(cfg, spec, B, Lc, dt)
        cache["blocks"][f"s{s}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape), one)
    for i, spec in enumerate(cfg.prefix):
        cache[f"prefix{i}"] = _layer_cache_shape(cfg, spec, B, Lc, dt)
    for i, spec in enumerate(cfg.tail_specs):
        cache[f"tail{i}"] = _layer_cache_shape(cfg, spec, B, Lc, dt)
    if cfg.encoder is not None:
        e = cfg.encoder
        kv = (B, e.num_frames, cfg.num_kv_heads, cfg.head_dim)
        cache["cross"] = {
            "k": jnp.zeros((cfg.num_layers,) + kv, dt),
            "v": jnp.zeros((cfg.num_layers,) + kv, dt)}
    return cache


# ===========================================================================
# Layer application
# ===========================================================================

def _apply_mixer(cfg: ArchConfig, spec: LayerSpec, p: Params, x, *,
                 positions, mode: str, cache, cache_pos):
    """Returns (y, new_cache)."""
    window = cfg.sliding_window if spec.attn_kind == "local" else None
    ring = (spec.mixer == "attn" and spec.attn_kind == "local"
            and cfg.windowed_local_cache)
    if spec.mixer == "attn":
        if mode == "train":
            y, _ = L.attention_block(
                p["attn"], x, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
                positions=positions, use_rope=(cfg.positional == "rope"
                                               and spec.use_rope),
                rope_theta=cfg.rope_theta, window=window,
                attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale)
            return y, None
        if mode == "prefill":
            return _attn_prefill(cfg, spec, p, x, positions, cache, ring,
                                 window)
        # decode
        if ring:
            return _attn_decode_ring(cfg, p, x, positions, cache, cache_pos)
        y, nc = L.attention_block(
            p["attn"], x, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=positions, use_rope=(cfg.positional == "rope"
                                           and spec.use_rope),
            rope_theta=cfg.rope_theta, window=window,
            attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale,
            kv_cache=cache, cache_pos=cache_pos)
        return y, nc
    if spec.mixer == "mla":
        m = cfg.mla
        if cfg.mla_absorbed and mode == "decode":
            y, nc = MLA.mla_attention_absorbed(
                p["attn"], x, num_heads=cfg.num_heads,
                kv_lora_rank=m.kv_lora_rank, nope_head_dim=m.nope_head_dim,
                rope_head_dim=m.rope_head_dim, v_head_dim=m.v_head_dim,
                rope_theta=cfg.rope_theta, positions=positions,
                cache=cache, cache_pos=cache_pos)
            return y, nc
        y, nc = MLA.mla_attention(
            p["attn"], x, num_heads=cfg.num_heads,
            kv_lora_rank=m.kv_lora_rank, nope_head_dim=m.nope_head_dim,
            rope_head_dim=m.rope_head_dim, v_head_dim=m.v_head_dim,
            rope_theta=cfg.rope_theta, positions=positions,
            cache=cache if mode != "train" else None,
            cache_pos=cache_pos if mode != "train" else None)
        return y, nc
    if spec.mixer == "ssd":
        s = cfg.ssd
        y, nc = SSD.ssd_block(p["attn"], x, d_inner=s.d_inner, state=s.state,
                              nheads=s.nheads, chunk=s.chunk,
                              rec_state=cache if mode == "decode" else None,
                              return_final_state=(mode == "prefill"))
        return y, nc
    if spec.mixer == "rglru":
        y, nc = RG.rglru_block(p["attn"], x,
                               state=cache if mode == "decode" else None,
                               return_final_state=(mode == "prefill"))
        return y, nc
    raise ValueError(spec.mixer)


def _attn_prefill(cfg, spec, p, x, positions, cache, ring, window):
    """Full-sequence attention that also emits the decode cache."""
    use_rope = cfg.positional == "rope" and spec.use_rope
    q = L._split_heads(L.dense(p["attn"]["wq"], x), cfg.num_heads, cfg.head_dim)
    k = L._split_heads(L.dense(p["attn"]["wk"], x), cfg.num_kv_heads, cfg.head_dim)
    v = L._split_heads(L.dense(p["attn"]["wv"], x), cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in p["attn"]:
        q = L.rmsnorm(p["attn"]["q_norm"], q)
        k = L.rmsnorm(p["attn"]["k_norm"], k)
    if use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.auto_sdpa(q, k, v, causal=True, window=window,
                      attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale)
    y = L.dense(p["attn"]["wo"],
                out.reshape(out.shape[:2] + (cfg.num_heads * cfg.head_dim,)))
    S = x.shape[1]
    if ring:
        W = cache["k"].shape[1]
        if S >= W:
            pos_tail = jnp.arange(S - W, S)
            slots = pos_tail % W
            nk = jnp.zeros_like(cache["k"]).at[:, slots].set(
                k[:, S - W:].astype(cache["k"].dtype))
            nv = jnp.zeros_like(cache["v"]).at[:, slots].set(
                v[:, S - W:].astype(cache["v"].dtype))
        else:
            nk = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        return y, {"k": nk, "v": nv}
    nk = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
    nv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
    return y, {"k": nk, "v": nv}


def _attn_decode_ring(cfg, p, x, positions, cache, cache_pos):
    """Single-token decode against a ring-buffered local window cache."""
    use_rope = cfg.positional == "rope"
    q = L._split_heads(L.dense(p["attn"]["wq"], x), cfg.num_heads, cfg.head_dim)
    k = L._split_heads(L.dense(p["attn"]["wk"], x), cfg.num_kv_heads, cfg.head_dim)
    v = L._split_heads(L.dense(p["attn"]["wv"], x), cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in p["attn"]:
        q = L.rmsnorm(p["attn"]["q_norm"], q)
        k = L.rmsnorm(p["attn"]["k_norm"], k)
    if use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = cache_pos % W
    nk = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                             k.astype(cache["k"].dtype),
                                             slot, axis=1)
    nv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                             v.astype(cache["v"].dtype),
                                             slot, axis=1)
    valid = jnp.minimum(cache_pos + 1, W)
    out = L.sdpa(q, nk, nv, causal=False, attn_softcap=cfg.attn_softcap,
                 scale=cfg.attn_scale, kv_len=valid)
    y = L.dense(p["attn"]["wo"],
                out.reshape(out.shape[:2] + (cfg.num_heads * cfg.head_dim,)))
    return y, {"k": nk, "v": nv}


def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: Params, x, *,
                 positions, mode: str, cache=None, cache_pos=None,
                 enc_out=None, cross_cache=None):
    """One transformer block.  Returns (x, new_cache, new_cross_cache, aux)."""
    h = L.norm_apply(cfg.norm, p["ln_attn"], x)
    y, new_cache = _apply_mixer(cfg, spec, p, h, positions=positions,
                                mode=mode, cache=cache, cache_pos=cache_pos)
    if cfg.use_post_norm:
        y = L.norm_apply(cfg.norm, p["ln_attn_post"], y)
    x = x + y

    new_cross = None
    if "cross" in p:
        h = L.norm_apply(cfg.norm, p["ln_cross"], x)
        if mode == "decode" and cross_cache is not None:
            out = L.sdpa(L._split_heads(L.dense(p["cross"]["wq"], h),
                                        cfg.num_heads, cfg.head_dim),
                         cross_cache["k"], cross_cache["v"], causal=False)
            y = L.dense(p["cross"]["wo"],
                        out.reshape(out.shape[:2]
                                    + (cfg.num_heads * cfg.head_dim,)))
            new_cross = cross_cache
        else:
            k = L._split_heads(L.dense(p["cross"]["wk"], enc_out),
                               cfg.num_kv_heads, cfg.head_dim)
            v = L._split_heads(L.dense(p["cross"]["wv"], enc_out),
                               cfg.num_kv_heads, cfg.head_dim)
            q = L._split_heads(L.dense(p["cross"]["wq"], h),
                               cfg.num_heads, cfg.head_dim)
            out = L.auto_sdpa(q, k, v, causal=False)
            y = L.dense(p["cross"]["wo"],
                        out.reshape(out.shape[:2]
                                    + (cfg.num_heads * cfg.head_dim,)))
            if mode == "prefill":
                new_cross = {"k": k, "v": v}
        x = x + y

    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        h = L.norm_apply(cfg.norm, p["ln_ffn"], x)
        y = L.ffn(p["ffn"], h, cfg.ffn_activation)
        if cfg.use_post_norm:
            y = L.norm_apply(cfg.norm, p["ln_ffn_post"], y)
        x = x + y
    elif spec.ffn == "moe":
        m = cfg.moe
        h = L.norm_apply(cfg.norm, p["ln_ffn"], x)
        y, moe_aux = MOE.moe_ffn(
            p["ffn"], h, num_experts=m.num_experts, top_k=m.top_k,
            capacity_factor=m.capacity_factor,
            activation=cfg.ffn_activation)
        aux = moe_aux["load_balance_loss"]
        x = x + y
    return x, new_cache, new_cross, aux


# ===========================================================================
# Full forward passes
# ===========================================================================

def _encoder_forward(cfg: ArchConfig, params: Params, frames) -> jax.Array:
    e = cfg.encoder
    x = frames.astype(_dtype(cfg))
    x = x + L.sinusoidal_embed(e.num_frames, cfg.d_model).astype(x.dtype)
    enc_spec = LayerSpec(mixer="attn", attn_kind="global",
                        use_rope=False, ffn="dense")
    positions = jnp.arange(e.num_frames)

    def body(h, p_layer):
        hn = L.norm_apply(cfg.norm, p_layer["ln_attn"], h)
        y, _ = L.attention_block(p_layer["attn"], hn,
                                 num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=cfg.head_dim, positions=positions,
                                 use_rope=False, rope_theta=cfg.rope_theta,
                                 causal=False)
        h = h + y
        hn = L.norm_apply(cfg.norm, p_layer["ln_ffn"], h)
        h = h + L.ffn(p_layer["ffn"], hn, cfg.ffn_activation)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["blocks"])
    return L.norm_apply(cfg.norm, params["encoder"]["norm"], x)


def _embed_tokens(cfg, params, tokens):
    x = L.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _unembed(cfg, params, x):
    x = L.norm_apply(cfg.norm, params["final_norm"], x)
    table = (params["embed"] if cfg.tie_embeddings
             else params["unembed"])
    return L.unembed(table, x, cfg.vocab_size, cfg.logit_softcap)


def forward(cfg: ArchConfig, params: Params, tokens, frames=None,
            mode: str = "train", cache: Optional[Params] = None,
            cache_pos=None) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits, new_cache_or_None, moe_aux_loss)."""
    B, S = tokens.shape
    positions = (jnp.arange(S)[None, :] + (cache_pos if mode == "decode"
                                           else 0))
    x = _embed_tokens(cfg, params, tokens)
    if cfg.positional == "learned":
        start = cache_pos if mode == "decode" else 0
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], start, S, 0)
        x = x + pe.astype(x.dtype)

    enc_out = None
    if cfg.encoder is not None and mode != "decode":
        enc_out = _encoder_forward(cfg, params, frames)

    new_cache: Params = {"blocks": {}} if mode != "train" else None
    aux_total = jnp.zeros((), jnp.float32)
    cross_list_k, cross_list_v = [], []
    layer_idx = 0

    def run_unrolled(x, name, spec, aux_total, layer_idx):
        c = cache.get(name) if cache is not None else None
        xc = (cache["cross"] if (cache is not None and "cross" in cache)
              else None)
        ccache = ({"k": xc["k"][layer_idx], "v": xc["v"][layer_idx]}
                  if xc is not None else None)
        x, nc, ncross, aux = _apply_layer(
            cfg, spec, params[name], x, positions=positions, mode=mode,
            cache=c, cache_pos=cache_pos, enc_out=enc_out,
            cross_cache=ccache)
        if new_cache is not None:
            new_cache[name] = nc
        if ncross is not None:
            cross_list_k.append(ncross["k"])
            cross_list_v.append(ncross["v"])
        return x, aux_total + aux, layer_idx + 1

    for i, spec in enumerate(cfg.prefix):
        x, aux_total, layer_idx = run_unrolled(x, f"prefix{i}", spec,
                                               aux_total, layer_idx)

    # scanned groups
    p = len(cfg.pattern)
    G = cfg.pattern_groups
    xc_all = cache.get("cross") if cache is not None else None
    if xc_all is not None:
        # slice the cross cache for the scanned groups: layers
        # [len(prefix) .. len(prefix)+G*p) reshaped (G, p, ...)
        lo = len(cfg.prefix)
        xk = xc_all["k"][lo:lo + G * p].reshape((G, p) + xc_all["k"].shape[1:])
        xv = xc_all["v"][lo:lo + G * p].reshape((G, p) + xc_all["v"].shape[1:])
    else:
        xk = xv = None

    def group_body(carry, xs):
        x, aux_acc = carry
        new_slot_caches = {}
        new_cross_kv = []
        for s, spec in enumerate(cfg.pattern):
            c = xs["cache"][f"s{s}"] if "cache" in xs else None
            ccache = ({"k": xs["xk"][:, s] if False else xs["xk"][s],
                       "v": xs["xv"][s]} if "xk" in xs else None)
            x, nc, ncross, aux = _apply_layer(
                cfg, spec, xs["params"][f"s{s}"], x, positions=positions,
                mode=mode, cache=c, cache_pos=cache_pos, enc_out=enc_out,
                cross_cache=ccache)
            aux_acc = aux_acc + aux
            if nc is not None:
                new_slot_caches[f"s{s}"] = nc
            if ncross is not None:
                new_cross_kv.append(ncross)
        ys = {}
        if new_slot_caches:
            ys["cache"] = new_slot_caches
        if new_cross_kv:
            ys["xk"] = jnp.stack([c["k"] for c in new_cross_kv])
            ys["xv"] = jnp.stack([c["v"] for c in new_cross_kv])
        return (x, aux_acc), ys

    xs = {"params": params["blocks"]}
    if cache is not None:
        xs["cache"] = cache["blocks"]
    if xk is not None:
        xs["xk"], xs["xv"] = xk, xv
    if cfg.remat and mode == "train":
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if cfg.remat_policy == "dots" else None)
        body_fn = jax.checkpoint(group_body, policy=policy)
    else:
        body_fn = group_body
    (x, aux_total), ys = jax.lax.scan(body_fn, (x, aux_total), xs)
    if new_cache is not None and "cache" in ys:
        new_cache["blocks"] = ys["cache"]
    if "xk" in ys:
        # (G, p, B, F, KV, hd) → (G*p, ...)
        cross_list_k.extend([ys["xk"].reshape((-1,) + ys["xk"].shape[2:])])
        cross_list_v.extend([ys["xv"].reshape((-1,) + ys["xv"].shape[2:])])

    for i, spec in enumerate(cfg.tail_specs):
        x, aux_total, layer_idx = run_unrolled(x, f"tail{i}", spec,
                                               aux_total, layer_idx)

    if new_cache is not None:
        if mode == "decode" and cache is not None and "cross" in cache:
            new_cache["cross"] = cache["cross"]
        elif cross_list_k:
            new_cache["cross"] = {
                "k": jnp.concatenate([k if k.ndim == 5 else k[None]
                                      for k in cross_list_k], 0),
                "v": jnp.concatenate([v if v.ndim == 5 else v[None]
                                      for v in cross_list_v], 0)}

    logits = _unembed(cfg, params, x)
    return logits, new_cache, aux_total


# ===========================================================================
# Public step functions
# ===========================================================================

def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(cfg, params, batch["tokens"],
                             frames=batch.get("frames"), mode="train")
    ce = L.cross_entropy(logits, batch["labels"])
    coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    total = ce + coef * aux
    return total, {"ce": ce, "moe_aux": aux}


def prefill(cfg: ArchConfig, params: Params, tokens, frames=None,
            cache_len: Optional[int] = None):
    """Serve-prefill: logits for the last position + a filled decode cache."""
    B, S = tokens.shape
    Lc = cache_len or S
    cache = init_cache(cfg, B, Lc)
    logits, new_cache, _ = forward(cfg, params, tokens, frames=frames,
                                   mode="prefill", cache=cache, cache_pos=0)
    return logits[:, -1], new_cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens, pos):
    """One decode step: tokens (B,1), pos scalar int32 (next write index)."""
    logits, new_cache, _ = forward(cfg, params, tokens, mode="decode",
                                   cache=cache, cache_pos=pos)
    return logits[:, -1], new_cache
