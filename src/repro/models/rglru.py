"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = σ(W_r x_t);  i_t = σ(W_i x_t)
    a_t = a^{c·r_t}    (a = σ(Λ) learned, c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

TPU adaptation: the sequential recurrence is computed with
``jax.lax.associative_scan`` (log-depth) — the linear recurrence composes as
(a₂a₁, a₂b₁ + b₂).  Decode is a single elementwise update: the entire
recurrent "cache" is one (B, width) vector, which is why this hybrid runs
the 500k-context shape where full-attention archs cannot.

Block structure (Griffin): conv1d(width 4) → RG-LRU, gated by a parallel
GeLU branch, then output projection.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, dense_init

RGLRU_C = 8.0


def rglru_init(key, d_model: int, *, width: int, conv_width: int,
               dtype) -> Params:
    ks = jax.random.split(key, 6)
    # Λ init so that a = exp(-c·softplus(Λ)) ∈ (0.9, 0.999) at r=1
    u = jax.random.uniform(ks[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))
    return {
        "in_x": dense_init(ks[1], d_model, width, dtype),
        "in_gate": dense_init(ks[2], d_model, width, dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, width), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_r": dense_init(ks[4], width, width, dtype),
        "w_i": dense_init(ks[5], width, width, dtype),
        "lam": lam,
        "out": dense_init(jax.random.fold_in(key, 9), width, d_model, dtype),
    }


def _rglru_coeffs(p: Params, x: jax.Array):
    """Per-step (a_t, b_t) of the linear recurrence, in fp32."""
    r = jax.nn.sigmoid(dense(p["w_r"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_i"], x).astype(jnp.float32))
    log_a = -RGLRU_C * r * jax.nn.softplus(p["lam"])   # log a_t  (≤ 0)
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated
    return a, b


def rglru_scan(p: Params, x: jax.Array,
               h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,W) → (y: (B,T,W), h_final: (B,W)).  Log-depth scan."""
    a, b = _rglru_coeffs(p, x)
    if h0 is not None:
        # fold h0 in as a virtual step 0: b_0 = h0, a_0 = 1
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def rglru_step(p: Params, x: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step: x (B,1,W), h (B,W)."""
    a, b = _rglru_coeffs(p, x)
    new_h = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return new_h.astype(x.dtype)[:, None], new_h.astype(h.dtype)


def _causal_conv1d(x, w, b, hist: Optional[jax.Array] = None):
    W = w.shape[0]
    if hist is None:
        pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        pads = jnp.concatenate([hist, x], axis=1)
    out = sum(pads[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b, pads[:, -(W - 1):]


def rglru_block(p: Params, x: jax.Array, *,
                state: Optional[Dict[str, jax.Array]] = None,
                return_final_state: bool = False
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """Griffin recurrent block.  x: (B,T,D).

    state = {"h": (B,W), "conv": (B,conv_width-1,W)} for decode."""
    gate = jax.nn.gelu(dense(p["in_gate"], x))
    xr = dense(p["in_x"], x)
    if state is None:
        conv, tail = _causal_conv1d(xr, p["conv_w"], p["conv_b"])
        y, h_final = rglru_scan(p, conv)
        new_state = ({"h": h_final, "conv": tail.astype(xr.dtype)}
                     if return_final_state else None)
    else:
        conv, tail = _causal_conv1d(xr, p["conv_w"], p["conv_b"],
                                    hist=state["conv"])
        y, h_final = rglru_step(p, conv, state["h"])
        new_state = {"h": h_final, "conv": tail.astype(xr.dtype)}
    return dense(p["out"], y * gate), new_state


def rglru_state_shape(B: int, width: int, conv_width: int,
                      dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    return {"h": jax.ShapeDtypeStruct((B, width), dtype),
            "conv": jax.ShapeDtypeStruct((B, conv_width - 1, width), dtype)}
