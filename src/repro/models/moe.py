"""Mixture-of-Experts layer with capacity-bucketed sort dispatch.

The Lachesis connection (DESIGN §4): token→expert dispatch is *hash
partitioning by a learned key* — the router is the partitioner candidate
``f_keyProj``, the all-to-all is the shuffle, and expert-parallel placement
is the persistent partitioning.  The dispatch below is the sort/scatter
formulation (right FLOP count, unlike dense one-hot dispatch): scatter
tokens into an (E, C, D) buffer, grouped-matmul per expert, gather back.
Under EP sharding (experts on the "model" axis, tokens on "data"), XLA
lowers the scatter/gather into the expected all-to-all pair.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..pjit_utils import constrain
from .layers import Params, dense, dense_init, ffn, ffn_init


def moe_init(key, d_model: int, d_ff_expert: int, num_experts: int,
             num_shared: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": dense_init(ks[0], d_model, num_experts, jnp.float32),
        # experts stacked on a leading E axis → shardable over "model"
        "w_in": (jax.random.normal(ks[1], (num_experts, d_model, d_ff_expert),
                                   jnp.float32) * scale).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (num_experts, d_model, d_ff_expert),
                                     jnp.float32) * scale).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (num_experts, d_ff_expert, d_model),
                                    jnp.float32) / math.sqrt(d_ff_expert)
                  ).astype(dtype),
    }
    if num_shared > 0:
        p["shared"] = ffn_init(jax.random.fold_in(key, 99), d_model,
                               d_ff_expert * num_shared, dtype)
    return p


def capacity(tokens: int, num_experts: int, top_k: int,
             factor: float = 1.25) -> int:
    c = int(math.ceil(tokens * top_k / num_experts * factor))
    return max(8, -(-c // 8) * 8)   # round up to 8 for lane alignment


def moe_ffn(p: Params, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, activation: str = "silu",
            router_noise: float = 0.0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) → (B, S, D), plus aux metrics (load-balance loss terms).

    Under SPMD (dry-run / distributed training) this routes through the
    shard_map implementation below — local dispatch + explicit all-to-all
    over the "model" (expert) axis, the paper's shuffle made explicit.
    The single-device path keeps the global scatter formulation (oracle)."""
    from ..pjit_utils import spmd_enabled
    if spmd_enabled():
        return moe_ffn_shard_map(p, x, num_experts=num_experts, top_k=top_k,
                                 capacity_factor=capacity_factor,
                                 activation=activation)
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)

    logits = dense(p["router"], xt.astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                     1e-9)                        # renorm

    C = capacity(T, num_experts, top_k, capacity_factor)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(expert_idx, num_experts,
                            dtype=jnp.int32)                      # (T,k,E)
    flat_oh = onehot.reshape(T * top_k, num_experts)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=0) - flat_oh)       # (T*k, E)
    pos = (pos_in_expert * flat_oh).sum(-1).reshape(T, top_k)     # (T,k)
    keep = pos < C                                                # drop overflow

    # dispatch: scatter token rows into (E, C, D)
    e_flat = expert_idx.reshape(-1)
    p_flat = jnp.where(keep, pos, C - 1).reshape(-1)
    k_flat = keep.reshape(-1)
    src = jnp.repeat(xt, top_k, axis=0) * k_flat[:, None].astype(x.dtype)
    buf = jnp.zeros((num_experts, C, D), x.dtype)
    buf = buf.at[e_flat, p_flat].add(src)
    # expert-parallel placement: the all-to-all XLA inserts here IS the
    # "shuffle" Lachesis reasons about (DESIGN §4)
    buf = constrain(buf, P("model", None, None))

    # grouped expert FFN: (E,C,D) @ (E,D,F)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])           # (E,C,D)

    # combine: gather back and weight by gate
    gathered = out_buf[e_flat, p_flat]                            # (T*k, D)
    gathered = gathered * (gate_vals.reshape(-1)[:, None]
                           * k_flat[:, None]).astype(x.dtype)
    y = gathered.reshape(T, top_k, D).sum(axis=1)

    if "shared" in p:
        y = y + ffn(p["shared"], xt, activation)

    # aux: load-balance loss (Switch-style) + drop fraction
    me = probs.mean(axis=0)                                       # (E,)
    ce = (onehot.sum(axis=1).astype(jnp.float32)).mean(axis=0)    # (E,)
    aux = {
        "load_balance_loss": num_experts * jnp.sum(me * ce) / top_k,
        "dropped_frac": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map implementation: local dispatch + explicit all-to-all (EP)
# ---------------------------------------------------------------------------

def _local_dispatch(xt, logits, num_experts, top_k, C, dtype):
    """Per-device dispatch: scatter local tokens into (E, C, D)."""
    T, D = xt.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.int32)
    flat_oh = onehot.reshape(T * top_k, num_experts)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) - flat_oh
    pos = (pos_in_expert * flat_oh).sum(-1).reshape(T, top_k)
    keep = pos < C
    e_flat = expert_idx.reshape(-1)
    p_flat = jnp.where(keep, pos, C - 1).reshape(-1)
    k_flat = keep.reshape(-1)
    src = jnp.repeat(xt, top_k, axis=0) * k_flat[:, None].astype(dtype)
    buf = jnp.zeros((num_experts, C, D), dtype)
    buf = buf.at[e_flat, p_flat].add(src)
    return buf, (e_flat, p_flat, k_flat, gate_vals, probs, onehot)


def moe_ffn_shard_map(p: Params, x: jax.Array, *, num_experts: int,
                      top_k: int, capacity_factor: float,
                      activation: str) -> Tuple[jax.Array, Dict]:
    """Expert-parallel MoE: tokens stay batch-sharded, experts live on the
    "model" axis; dispatch is device-local, the exchange is one explicit
    all-to-all each way (forward + transposed in backward)."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.sharding.get_abstract_mesh()
    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    mp = axis_sizes.get("model", 1)
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp_spec = dp_axes if len(dp_axes) != 1 else dp_axes[0]
    E = num_experts
    E_loc = E // mp
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]

    # sequence-sharded dispatch: tokens split over the model axis too, so
    # every (data, model) rank dispatches DISTINCT tokens — without this the
    # replicated-x dispatch does mp× redundant expert compute.
    S_total = x.shape[1]
    seq_shard = mp > 1 and S_total % mp == 0
    # decode (B=1 or tiny): batch may not divide the DP axes — replicate
    import math as _math
    dp_size = _math.prod(axis_sizes[a] for a in dp_axes) if dp_axes else 1
    if x.shape[0] % max(dp_size, 1) != 0:
        dp_spec = None

    def local_fn(router, w_in, w_gate, w_out, shared, xl):
        B_loc, S, D = xl.shape
        T = B_loc * S
        xt = xl.reshape(T, D)
        logits = dense(router, xt.astype(jnp.float32))
        C = capacity(T, E, top_k, capacity_factor)
        buf, (e_flat, p_flat, k_flat, gate_vals, probs, onehot) = \
            _local_dispatch(xt, logits, E, top_k, C, xl.dtype)

        # exchange: (E, C, D) → (E_loc, C·mp, D) over the model axis
        if mp > 1:
            buf = jax.lax.all_to_all(buf, "model", split_axis=0,
                                     concat_axis=1, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        out_buf = jnp.einsum("ecf,efd->ecd", act(g) * h, w_out)
        if mp > 1:
            out_buf = jax.lax.all_to_all(out_buf, "model", split_axis=1,
                                         concat_axis=0, tiled=True)

        gathered = out_buf[e_flat, p_flat]
        gathered = gathered * (gate_vals.reshape(-1)[:, None]
                               * k_flat[:, None]).astype(xl.dtype)
        y = gathered.reshape(T, top_k, D).sum(axis=1)

        if shared is not None:
            # TP shared expert: local d_ff slice, psum the partial output
            y_sh = ffn(shared, xt, activation)
            y = y + jax.lax.psum(y_sh, "model")

        me = probs.mean(axis=0)
        ce = onehot.sum(axis=1).astype(jnp.float32).mean(axis=0)
        aux_lb = E * jnp.sum(me * ce) / top_k
        aux_drop = 1.0 - k_flat.astype(jnp.float32).mean()
        aux = jnp.stack([aux_lb, aux_drop])
        for a in dp_axes:
            aux = jax.lax.pmean(aux, a)
        if seq_shard:
            aux = jax.lax.pmean(aux, "model")
        return y.reshape(B_loc, S, D), aux

    shared = p.get("shared")
    shared_specs = None
    if shared is not None:
        # TP layout for the shared expert: d_ff sliced over "model"
        shared_specs = {"w_in": {"w": P(None, "model")},
                        "w_gate": {"w": P(None, "model")},
                        "w_out": {"w": P("model", None)}}
    x_spec = (P(dp_spec, "model", None) if seq_shard
              else P(dp_spec, None, None))
    if shared is not None and seq_shard:
        # shared expert sees only the local sequence slice; its psum over
        # "model" would double-count — run it unsharded instead
        shared_specs = {"w_in": {"w": P(None, None)},
                        "w_gate": {"w": P(None, None)},
                        "w_out": {"w": P(None, None)}}
    in_specs = (
        P(),                                  # router replicated
        P("model", None, None), P("model", None, None),
        P("model", None, None),               # experts on model axis
        shared_specs,
        x_spec,                               # tokens over data (+model)
    )
    out_specs = (x_spec, P())

    def local_fn_wrapped(router, w_in, w_gate, w_out, shared_l, xl):
        if shared_l is not None and seq_shard:
            # unsharded shared expert on the local slice (no psum)
            y, aux = local_fn(router, w_in, w_gate, w_out, None, xl)
            y = y + ffn(shared_l, xl.reshape(-1, xl.shape[-1]),
                        activation).reshape(xl.shape)
            return y, aux
        return local_fn(router, w_in, w_gate, w_out, shared_l, xl)

    fn = shard_map(local_fn_wrapped, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    y, aux = fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], shared, x)
    return y, {"load_balance_loss": aux[0], "dropped_frac": aux[1]}
