"""Shared transformer layers: norms, RoPE, GQA/MQA attention, gated FFNs.

Everything is a pure function over dict pytrees so the whole stack lowers
through jax.eval_shape / pjit without allocation, scans over stacked layer
params, and remats cleanly.  Initializers return the params for one layer;
models stack them with jax.vmap over an init key axis.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def norm_init(kind: str, d: int, dtype) -> Params:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Interleaved (adjacent-pair) RoPE.  x: (..., S, H, hd).

    Pair (2i, 2i+1) rotates by freq_i — mathematically equivalent to the
    rotate-half formulation up to a fixed index permutation (q and k share
    it, so attention scores are identical).  Chosen because the rotation is
    SHARD-LOCAL when hd is sharded over the "model" axis: rotate-half's
    split at hd/2 crosses shard boundaries and forced GSPMD into
    involuntary full rematerialization on the decode path (§Perf round 3)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr = x.astype(jnp.float32).reshape(x.shape[:-1] + (hd // 2, 2))
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def sinusoidal_embed(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((length, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Dense projections
# ---------------------------------------------------------------------------

def dense_init(key, din: int, dout: int, dtype, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(din)
    p = {"w": (jax.random.normal(key, (din, dout), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA, local windows, softcap, NoPE)
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False,
                   qk_norm: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype, qkv_bias),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype, qkv_bias),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype, qkv_bias),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool, window: Optional[int] = None,
         attn_softcap: float = 0.0, q_offset: int | jax.Array = 0,
         kv_len: Optional[jax.Array] = None,
         scale: Optional[float] = None) -> jax.Array:
    """Scaled dot-product attention with GQA group broadcasting.

    q: (B, Sq, H, hd); k: (B, Skv, KV, hd); v: (B, Skv, KV, vd) — vd may
    differ from hd (MLA).  ``q_offset`` is the absolute position of q[0]
    (decode: the cache write index).  ``kv_len`` masks the valid cache
    prefix during decode.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[3]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # einsum on the NATIVE (B, S, KV, hd) layout with f32 accumulation:
    # no transposed/upcast K-V copies ever materialize in HBM (§Perf round-2
    # fix for memory-bound decode — halves cache-side traffic)
    qf = ((q * scale).astype(jnp.float32)
          .reshape(B, Sq, KV, G, hd))                            # h = kv·G+g
    scores = jnp.einsum("bqkgd,bmkd->bkgqm", qf, k,
                        preferred_element_type=jnp.float32)      # B,KV,G,Sq,Skv
    if attn_softcap > 0:
        scores = softcap(scores, attn_softcap)

    Skv = k.shape[1]
    q_pos = jnp.arange(Sq)[:, None] + q_offset                   # (Sq,1)
    k_pos = jnp.arange(Skv)[None, :]                             # (1,Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # bf16 probs × native-layout V, f32 accumulation (flash-style)
    out = jnp.einsum("bkgqm,bmkd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, vd).astype(q.dtype)


BLOCKWISE_THRESHOLD = 2048    # full-S² scores above this would blow HBM
BLOCKWISE_BLOCK = 512


def blockwise_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: Optional[int] = None,
                   attn_softcap: float = 0.0,
                   q_offset: int | jax.Array = 0,
                   kv_len: Optional[jax.Array] = None,
                   scale: Optional[float] = None,
                   block: int = BLOCKWISE_BLOCK) -> jax.Array:
    """Memory-bounded attention: lax.scan over query blocks so only a
    (block × Skv) score tile is ever live — the pure-JAX analogue of the
    Pallas flash kernel (kernels/flash_attention), used on the reference
    path for long sequences."""
    B, Sq, H, hd = q.shape
    if Sq <= block:
        return sdpa(q, k, v, causal=causal, window=window,
                    attn_softcap=attn_softcap, q_offset=q_offset,
                    kv_len=kv_len, scale=scale)
    pad = (-Sq) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // block
    qb = q.reshape(B, nb, block, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, xs):
        i, qi = xs
        out = sdpa(qi, k, v, causal=causal, window=window,
                   attn_softcap=attn_softcap,
                   q_offset=q_offset + i * block, kv_len=kv_len, scale=scale)
        return None, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * block, H, -1)
    return out[:, :Sq]


FLASH_DECODE_THRESHOLD = 8192
FLASH_DECODE_BLOCK = 2048
# default OFF: on CPU-fusion byte accounting the scanned dynamic-slices are
# charged as full-cache reads per block (artifact); enable per-run for TPU
# or for the §Perf flash_decode variant
FLASH_DECODE_ENABLED = False


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 kv_len: Optional[jax.Array] = None,
                 window: Optional[int] = None,
                 attn_softcap: float = 0.0,
                 q_offset: int | jax.Array = 0,
                 scale: Optional[float] = None,
                 block: int = FLASH_DECODE_BLOCK,
                 causal: bool = True) -> jax.Array:
    """Single-token decode attention with online softmax over KEY blocks.

    The naive path materializes (B, Skv, KV, G) f32 scores+probs — at 32k
    context that is ~0.5 GB/layer/chip of HBM traffic several times over
    (§Perf round 4).  Here a ``lax.scan`` walks the cache in ``block``-sized
    slices carrying running (m, l, acc); scores never exist at full length.
    q: (B, 1, H, hd); k: (B, Skv, KV, hd); v: (B, Skv, KV, vd)."""
    B, Sq, H, hd = q.shape
    assert Sq == 1
    Skv, KV = k.shape[1], k.shape[2]
    vd = v.shape[3]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    nb = -(-Skv // block)

    qf = (q * scale).astype(jnp.float32).reshape(B, KV, G, hd)

    def body(carry, i):
        m, l, acc = carry
        i0 = i * block
        kb = jax.lax.dynamic_slice_in_dim(k, i0, block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i0, block, axis=1)
        s = jnp.einsum("bkgd,bmkd->bkgm", qf, kb,
                       preferred_element_type=jnp.float32)   # (B,KV,G,block)
        if attn_softcap > 0:
            s = softcap(s, attn_softcap)
        k_pos = i0 + jnp.arange(block)
        mask = k_pos < (kv_len if kv_len is not None else Skv)
        if window is not None:
            mask &= (q_offset - k_pos) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgm,bmkd->bkgd", p.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    if Skv % block:
        pad = (-Skv) % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    init = (jnp.full((B, KV, G), -1e30, jnp.float32),
            jnp.zeros((B, KV, G), jnp.float32),
            jnp.zeros((B, KV, G, vd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, vd).astype(q.dtype)


def auto_sdpa(q, k, v, **kw):
    if (FLASH_DECODE_ENABLED and q.shape[1] == 1
            and k.shape[1] >= FLASH_DECODE_THRESHOLD
            and kw.get("xk") is None):
        kw2 = {kk: vv for kk, vv in kw.items()}
        return flash_decode(q, k, v, **kw2)
    if q.shape[1] > BLOCKWISE_THRESHOLD:
        return blockwise_sdpa(q, k, v, **kw)
    return sdpa(q, k, v, **kw)


def attention_block(p: Params, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int,
                    positions: jax.Array, use_rope: bool, rope_theta: float,
                    causal: bool = True, window: Optional[int] = None,
                    attn_softcap: float = 0.0,
                    scale: Optional[float] = None,
                    kv_cache: Optional[Dict[str, jax.Array]] = None,
                    cache_pos: Optional[jax.Array] = None,
                    xk: Optional[jax.Array] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """Self- (or cross-, via ``xk``) attention sublayer.

    Decode: pass ``kv_cache`` ({"k","v"}: (B, L, KV, hd)) and ``cache_pos``;
    new k/v are written at cache_pos and attention runs over the prefix.
    """
    src = x if xk is None else xk
    q = _split_heads(dense(p["wq"], x), num_heads, head_dim)
    k = _split_heads(dense(p["wk"], src), num_kv_heads, head_dim)
    v = _split_heads(dense(p["wv"], src), num_kv_heads, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        if xk is None:
            k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"],
                                                 k.astype(kv_cache["k"].dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"],
                                                 v.astype(kv_cache["v"].dtype),
                                                 cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        out = auto_sdpa(q, ck, cv, causal=causal, window=window,
                        attn_softcap=attn_softcap, q_offset=cache_pos,
                        kv_len=cache_pos + q.shape[1], scale=scale)
    else:
        out = auto_sdpa(q, k, v, causal=causal and xk is None, window=window,
                        attn_softcap=attn_softcap, scale=scale)
    y = dense(p["wo"], out.reshape(out.shape[:2] + (num_heads * head_dim,)))
    return y, new_cache


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU / GeGLU) and plain MLP
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn(p: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    h = dense(p["w_in"], x)
    act = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
           "relu": jax.nn.relu}[activation]
    if "w_gate" in p:
        h = act(dense(p["w_gate"], x)) * h
    else:
        h = act(h)
    return dense(p["w_out"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: Params, x: jax.Array, real_vocab: int,
            cap: float = 0.0) -> jax.Array:
    logits = x @ p["table"].T
    if cap > 0:
        logits = softcap(logits, cap)
    V = p["table"].shape[0]
    if real_vocab < V:
        pad_mask = jnp.arange(V) < real_vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL in fp32; logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
