"""The stable public API: ``lachesis.Session`` (DESIGN §9).

One facade over the whole pipeline::

    Workload DSL  →  LogicalPlan  →  PhysicalPlan  →  Executor
                      (normalize,      (bind backend      (run the
                       Alg. 1+2)        ops + Alg. 4       frozen steps)
                                        static elision,
                                        cached by layout
                                        generation)

    import lachesis

    sess = lachesis.Session(num_workers=8, backend="device")
    sess.write("submissions", subs, cand)        # storage-time partitioning
    sess.write("authors", auths)

    reviews = sess.scan("submissions")           # DSL passthrough builds an
    authors = sess.scan("authors")               # implicit workload...
    j = sess.join(reviews, authors,
                  left_key=reviews["author"], right_key=authors["author"])
    sess.write_result(j, "integrated")
    result = sess.run()                          # ...and run() executes it

    print(sess.explain(wl))                      # deterministic plan dump
    vals, stats = sess.run(wl)                   # tuple unpacking supported
    ap = sess.autopilot()                        # attach the online optimizer

Repeated ``run`` of an unchanged workload on an unchanged store layout is
a pure PhysicalPlan-cache hit: no candidate extraction, no Alg. 4, and no
jax re-trace (``plan_cache_stats()['traces']`` stays flat).  A layout
generation flip (repartition, rewrite) invalidates exactly the plans that
scan the flipped dataset.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from .core.backends import (Backend, BackendRegistry, REGISTRY,
                            UnknownBackendError)
from .core.dsl import Col, SetHandle, Workload
from .core.executor import (EngineStats, Executor, StalePlanError, TableVal,
                            plan_and_execute)
from .core.planner import LogicalPlan, PhysicalPlan, Planner
from .data.partition_store import PartitionStore, StoredDataset
from .obs import metrics as _obs_metrics
from .obs import tracer as _obs_tracer
from .obs.export import to_chrome_trace, write_chrome_trace
from .obs.telemetry import RunProfile

__all__ = ["Session", "RunResult", "UnknownBackendError", "StalePlanError"]

RunStats = EngineStats   # the stats schema, under its API-facing name


@dataclass
class RunResult:
    """What ``Session.run`` returns: node values + stats + the plan that
    produced them.  Iterable as ``(values, stats)`` so legacy
    ``vals, stats = run(...)`` call sites migrate without edits."""
    values: Dict[int, Any]
    stats: EngineStats
    plan: PhysicalPlan
    workload: Workload

    def __iter__(self):
        return iter((self.values, self.stats))

    def value_of(self, handle) -> Any:
        """Value produced at a DSL handle (``Col``/``SetHandle``) or nid."""
        nid = handle._nid if isinstance(handle, Col) else int(handle)
        return self.values[nid]

    def table(self, handle) -> TableVal:
        v = self.value_of(handle)
        if not isinstance(v, TableVal):
            raise TypeError(f"node {handle} produced {type(v).__name__}, "
                            "not a set-valued table")
        return v


class Session:
    """The single entry point for storing, planning and running workloads.

    Owns one :class:`~repro.data.partition_store.PartitionStore`, one
    :class:`~repro.core.planner.Planner` (with its PhysicalPlan cache) and
    one :class:`~repro.core.executor.Executor`.  Thread-compatible with a
    background :class:`~repro.service.Autopilot`: generation-keyed plans
    mean an autonomous repartition simply causes the next run to re-plan.
    """

    def __init__(self, store: Optional[PartitionStore] = None, *,
                 num_workers: int = 8, backend: str = "host",
                 matching: bool = True, interpret: Optional[bool] = None,
                 net_bandwidth: float = 1.25e9,
                 history=None, registry: Optional[BackendRegistry] = None,
                 plan_cache_capacity: int = 128,
                 store_path: Optional[str] = None,
                 memory_budget_bytes: Optional[int] = None,
                 autoflush: bool = True,
                 adaptive_capacity: bool = False,
                 metrics: Optional["_obs_metrics.MetricsRegistry"] = None,
                 cluster=None):
        """``store_path`` (DESIGN §10) backs the session's store with the
        durable tier: an existing store directory is reattached (its
        layouts, partitioner signatures and generation numbers carry over,
        so this session's plans elide the shuffles a previous application's
        layouts paid for), a fresh directory is initialized.  Mutually
        exclusive with passing a ``store`` object.

        ``adaptive_capacity`` (DESIGN §12) lets the store plan non-uniform
        per-partition capacities on skewed writes and arms the Autopilot's
        skew actions (hot-key salting, capacity rebucketing).

        ``cluster`` (DESIGN §14): a
        :class:`~repro.cluster.ClusterConfig` shards the durable tier
        across directories-as-nodes behind a PartitionDirectory; requires
        ``store_path``.  Reattaching an existing cluster store needs no
        ``cluster`` argument — membership comes from the on-disk
        directory epoch."""
        self.registry = registry or REGISTRY
        self._backend: Backend = self.registry.get(backend)
        if store is not None and store_path is not None:
            raise ValueError("pass either store= or store_path=, not both")
        if store is None:
            store = PartitionStore(num_workers=num_workers,
                                   backend=self._backend.name
                                   if self._backend.device_resident
                                   else "host",
                                   interpret=interpret,
                                   registry=self.registry,
                                   root=store_path,
                                   memory_budget_bytes=memory_budget_bytes,
                                   autoflush=autoflush,
                                   adaptive_capacity=adaptive_capacity,
                                   cluster=cluster)
        elif cluster is not None:
            raise ValueError("cluster= applies to the session-built store; "
                             "pass a cluster store= object instead")
        self.net_bandwidth = net_bandwidth
        self.history = history
        self.run_hooks: List[Callable[[Any, EngineStats], None]] = []
        self.metrics_registry = metrics or _obs_metrics.REGISTRY
        self.planner = Planner(store, registry=self.registry,
                               matching=matching,
                               cache_capacity=plan_cache_capacity,
                               metrics=self.metrics_registry)
        self.executor = Executor(store, interpret=interpret)
        self._current: Optional[Workload] = None
        self._wl_counter = 0
        # last-seen device trace counter, for per-run retrace deltas in
        # the telemetry RunProfile (lazy: first durable run initializes)
        self._traces_seen: Optional[int] = None
        # facades attached via autopilot()/serve(), weakly held: the
        # explain_decisions()/export_trace() surfaces read through them
        self._autopilots: List[Any] = []
        _register_process_collectors(self.metrics_registry)
        store.register_metrics(self.metrics_registry)

    # -- backend / knobs -----------------------------------------------------
    @property
    def backend(self) -> str:
        return self._backend.name

    @property
    def store(self):
        return self.planner.store

    # matching/interpret forward into the planner/executor: mutating them
    # takes effect on the next run (matching is part of the plan-cache key)
    @property
    def matching(self) -> bool:
        return self.planner.matching

    @matching.setter
    def matching(self, v: bool) -> None:
        self.planner.matching = bool(v)

    @property
    def interpret(self) -> Optional[bool]:
        return self.executor.interpret

    @interpret.setter
    def interpret(self, v: Optional[bool]) -> None:
        self.executor.interpret = v

    @property
    def num_workers(self) -> int:
        return self.store.m

    # -- workload building (DSL passthrough) --------------------------------
    def workload(self, app_id: Optional[str] = None) -> Workload:
        """Start (and make current) a fresh traced workload."""
        if app_id is None:
            self._wl_counter += 1
            app_id = f"session-wl-{self._wl_counter}"
        self._current = Workload(app_id)
        return self._current

    @property
    def current(self) -> Optional[Workload]:
        return self._current

    def scan(self, dataset: str) -> SetHandle:
        """Scan a stored dataset into the current workload (creating one
        implicitly if none is active)."""
        wl = self._current if self._current is not None else self.workload()
        return wl.scan(dataset)

    # Each passthrough operates on the workload that owns the handle, so
    # mixing handles from an explicit Workload also works.
    def partition(self, key: Col, strategy: str = "hash") -> SetHandle:
        return key._wl.partition(key, strategy)

    def join(self, left: SetHandle, right: SetHandle, **kw) -> SetHandle:
        return left._wl.join(left, right, **kw)

    def aggregate(self, x: SetHandle, **kw) -> SetHandle:
        return x._wl.aggregate(x, **kw)

    def filter(self, x: SetHandle, pred: Col) -> SetHandle:
        return x._wl.filter(x, pred)

    def map(self, x: SetHandle, fn: Callable, tag: str) -> SetHandle:
        return x._wl.map(x, fn, tag)

    def flatten(self, x: SetHandle) -> SetHandle:
        return x._wl.flatten(x)

    def write_result(self, x: SetHandle, dataset: str) -> SetHandle:
        """Terminal write of a workload branch (``Workload.write``).  Named
        distinctly from :meth:`write`, which stores host data directly."""
        return x._wl.write(x, dataset)

    # -- planning ------------------------------------------------------------
    def plan(self, workload: Optional[Workload] = None,
             backend: Optional[str] = None) -> PhysicalPlan:
        """Compiled (cached) PhysicalPlan for ``workload`` on the current
        store layout."""
        plan, _hit = self.planner.physical(self._resolve_wl(workload),
                                           self._resolve_backend(backend))
        return plan

    def logical_plan(self, workload: Optional[Workload] = None) -> LogicalPlan:
        return self.planner.logical(self._resolve_wl(workload))

    def explain(self, workload: Optional[Workload] = None,
                backend: Optional[str] = None) -> str:
        """Deterministic plan dump: per partition node the elide/shuffle
        decision (Alg. 4 applied statically), the bound backend op and the
        ShufflePlan bucket; plus the layout pins keying the plan cache."""
        return self.plan(workload, backend).explain()

    # -- execution -----------------------------------------------------------
    def run(self, workload: Optional[Workload] = None, *,
            backend: Optional[str] = None, history=None,
            timestamp: Optional[float] = None) -> RunResult:
        """Plan (or fetch the cached plan) and execute.

        Without ``workload``, runs the session's current implicit workload
        (built via the scan/join/... passthroughs) and clears it once the
        run succeeds — a failed run keeps it so it can be retried.  A
        layout swap racing the run (background Autopilot) triggers a
        transparent re-plan, never an error."""
        wl = self._resolve_wl(workload)
        history = self.history if history is None else history
        with _obs_tracer.span("session.run", "session",
                              workload=getattr(wl, "app_id", "?")) as sp:
            vals, stats, plan = plan_and_execute(
                self.planner, self.executor, wl,
                self._resolve_backend(backend),
                history=history, hooks=tuple(self.run_hooks),
                timestamp=timestamp)
            sp.set(cache_hit=stats.plan_cache_hit,
                   wall_ms=round(stats.wall_s * 1e3, 3))
        if getattr(self.store, "telemetry", None) is not None:
            self._record_run_profile(wl, stats, plan)
        if workload is None and wl is self._current:
            self._current = None
        return RunResult(values=vals, stats=stats, plan=plan, workload=wl)

    def _record_run_profile(self, wl: Workload, stats: EngineStats,
                            plan: PhysicalPlan) -> None:
        """Append one RunProfile to the store's durable telemetry
        (DESIGN §15) — the (state, action, reward) record per run."""
        import time as _time
        from .data.device_repartition import plan_cache_stats as dev_stats
        traces = int(dev_stats().get("traces", 0))
        prev = self._traces_seen
        self._traces_seen = traces
        key = getattr(plan, "key", None)
        generations = {name: int(gen)
                       for name, gen, _sig in getattr(key, "layout", ())}
        profile = RunProfile(
            t=_time.time(), workload=getattr(wl, "app_id", ""),
            process=_obs_tracer.TRACER.process,
            wall_s=float(stats.wall_s), shuffle_s=float(stats.shuffle_s),
            io_s=float(stats.storage_io_s),
            planning_s=float(stats.planning_s),
            plan_cache_hit=bool(stats.plan_cache_hit),
            retraces=traces - prev if prev is not None else 0,
            shuffles_performed=int(stats.shuffles_performed),
            shuffles_elided=int(stats.shuffles_elided),
            shuffle_bytes=int(stats.shuffle_bytes),
            input_bytes=int(stats.input_bytes),
            output_bytes=int(stats.output_bytes),
            io_bytes=int(stats.storage_io_bytes),
            padded_bytes=int(stats.padded_bytes),
            valid_bytes=int(stats.valid_bytes),
            placement_epoch=int(getattr(key, "placement_epoch", -1)),
            generations=generations)
        try:
            self.store.telemetry.record_run(profile)
        except OSError:          # telemetry is advisory — a full disk
            pass                 # must never fail the run that produced it

    def add_run_hook(self, fn: Callable[[Any, EngineStats], None]) -> None:
        """Register ``fn(workload, stats)`` to fire after every run (the
        service Observer attaches here)."""
        self.run_hooks.append(fn)

    # -- plan cache ----------------------------------------------------------
    def plan_cache_stats(self) -> Dict[str, int]:
        """Planner cache counters merged with the jax-level ShufflePlan
        trace counter: ``traces`` flat across repeated runs is the
        no-retrace guarantee."""
        from .data.device_repartition import plan_cache_stats as dev_stats
        out = self.planner.cache_stats()
        out["traces"] = dev_stats()["traces"]
        return out

    def clear_plan_cache(self) -> None:
        self.planner.clear_cache()

    def invalidate(self, dataset: Optional[str] = None) -> int:
        """Eagerly drop cached plans scanning ``dataset`` (all if None)."""
        return self.planner.invalidate(dataset)

    # -- storage passthrough ---------------------------------------------------
    def write(self, name: str, data: Dict[str, Any], partitioner=None,
              seed: int = 0) -> StoredDataset:
        """Persist host columns under ``name`` (storage-time partitioning)."""
        return self.store.write(name, data, partitioner, seed=seed)

    def read(self, name: str,
             generation: Optional[int] = None) -> StoredDataset:
        return self.store.read(name, generation=generation)

    def repartition(self, name: str, partitioner, *, mesh=None,
                    swap: bool = True):
        """Repartition a stored dataset (publishes a new generation; the
        affected cached plans miss on their next lookup)."""
        ds = self.store.read(name)
        return self.store.repartition(ds, partitioner, mesh=mesh, swap=swap)

    def flush(self, name: Optional[str] = None) -> int:
        """Persist pending generations to the durable tier (no-op without
        ``store_path``).  Returns the number of generations published."""
        return self.store.flush(name)

    @property
    def store_path(self) -> Optional[str]:
        return self.store.root if self.store.is_durable else None

    # -- cluster passthrough (DESIGN §14) ------------------------------------
    @property
    def directory(self):
        """The store's PartitionDirectory (None off-cluster)."""
        return self.store.directory

    def plan_rebalance(self, **kw):
        """Plan an incremental placement change without applying it."""
        return self.store.plan_rebalance(**kw)

    def rebalance(self, plan=None, **kw):
        """Apply (or plan-and-apply) a placement change; cached plans
        against the old placement epoch invalidate automatically."""
        return self.store.rebalance(plan=plan, **kw)

    # -- observability ---------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """Versioned JSON snapshot of every metric the session's registry
        holds (planner cache, store write/IO totals, ShufflePlan cache,
        serving counters when a frontend shares the registry)."""
        return self.metrics_registry.snapshot()

    def metrics_text(self) -> str:
        """The same snapshot in Prometheus text exposition format."""
        return self.metrics_registry.prometheus_text()

    def export_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Export the tracer's finished spans as Chrome ``trace_event``
        JSON (open in Perfetto / ``chrome://tracing``).  Writes to
        ``path`` when given; always returns the document.  Requires
        tracing on: ``repro.obs.enable()``."""
        meta = {"session_backend": self.backend,
                "num_workers": self.num_workers}
        if path is not None:
            return write_chrome_trace(path, metadata=meta)
        return to_chrome_trace(metadata=meta)

    def telemetry(self, limit: Optional[int] = None) -> List[RunProfile]:
        """Per-run :class:`RunProfile` records from the store's durable
        telemetry history (DESIGN §15), oldest first — these survive
        process restarts because they live under the store root.  Empty
        without ``store_path``."""
        tele = getattr(self.store, "telemetry", None)
        if tele is None:
            return []
        return tele.run_profiles(limit=limit)

    @property
    def telemetry_store(self):
        """The underlying TelemetryStore (None without ``store_path``)."""
        return getattr(self.store, "telemetry", None)

    @property
    def watchdog(self):
        """The store's RegressionDetector (None without ``store_path``)."""
        return getattr(self.store, "watchdog", None)

    def export_node_metrics(self, node: Optional[str] = None) -> Optional[str]:
        """Snapshot this process's metrics registry to the store's
        ``telemetry/metrics-<node>.json`` (default node label: the
        tracer's process label) for the cluster-wide merged view.
        Returns the path, or None without a durable store."""
        tele = getattr(self.store, "telemetry", None)
        if tele is None:
            return None
        return tele.write_node_metrics(self.metrics_registry,
                                       node or _obs_tracer.TRACER.process)

    def cluster_metrics(self) -> Dict[str, Any]:
        """Merged metrics snapshot over every node's exported
        ``metrics-*.json`` — one document, ``node`` label per sample."""
        tele = getattr(self.store, "telemetry", None)
        if tele is None:
            return {"version": _obs_metrics.METRICS_SCHEMA_VERSION,
                    "nodes": [], "metrics": {}}
        return tele.cluster_metrics()

    def cluster_metrics_text(self) -> str:
        """The merged cluster view as Prometheus text exposition."""
        return _obs_metrics.snapshot_prometheus_text(self.cluster_metrics())

    def explain_decisions(self, limit: int = 50) -> List[Dict[str, Any]]:
        """Structured why-records for the Autopilot's recent decisions:
        every candidate's priced score and which gate (hysteresis,
        worth-it, skew threshold) accepted or rejected it.  Reads the
        in-memory records of attached autopilots first, then falls back
        to the durable ``decisions.log`` (kind=why rows) so a fresh
        session on a durable store can still explain past decisions."""
        recs: List[Dict[str, Any]] = []
        for ap in self._autopilots:
            explain = getattr(ap, "explain", None)
            if explain is not None:
                recs.extend(explain())
        if not recs and self.store.is_durable:
            for row in self.store.durable.decisions():
                if row.get("kind") == "why":
                    # ticks batch their records into one JSONL row
                    recs.extend(row.get("records") or [])
        return recs[-limit:]

    # -- service attach --------------------------------------------------------
    def autopilot(self, **kw):
        """Attach an online storage optimizer (observer + cost model +
        decide/apply loop) to this session; returns the
        :class:`~repro.service.Autopilot`."""
        from .service import Autopilot
        ap = Autopilot(self, **kw)
        self._autopilots.append(ap)
        return ap

    def serve(self, **kw):
        """Open a concurrent serving frontend over this session's store
        (DESIGN §11): bounded admission, request coalescing, per-tenant
        namespaces/budgets.  Returns the
        :class:`~repro.service.ServingFrontend`; composes with
        :meth:`autopilot` — background repartitions stay invisible to
        in-flight serves."""
        from .service import ServingFrontend
        return ServingFrontend(self, **kw)

    # -- internals ---------------------------------------------------------------
    def _resolve_wl(self, workload: Optional[Workload]) -> Workload:
        if workload is not None:
            return workload
        if self._current is None:
            raise ValueError("no workload: pass one to run()/plan() or "
                             "build the implicit one via session.scan(...)")
        return self._current

    def _resolve_backend(self, backend: Optional[str]) -> Backend:
        return self._backend if backend is None else self.registry.get(backend)


class _ProcessCollectors:
    """Anchor object for process-global metric callbacks (the jitted
    ShufflePlan cache and the tracer's own health counters are
    process-wide, not per-session).  One anchor per registry, strongly
    held on the registry so the weakref callback stays alive."""

    def samples(self):
        from .data.device_repartition import plan_cache_stats as dev_stats
        for k, v in dev_stats().items():
            if isinstance(v, (int, float)):
                yield f"shuffleplan_cache_{k}", {}, v
        st = _obs_tracer.TRACER.stats()
        yield "tracer_spans_buffered", {}, st["buffered"]
        yield "tracer_spans_dropped_total", {}, st["dropped"]
        # canonical names (DESIGN §15): ring-buffer loss + current mode,
        # so silent span drops and "why is my trace empty" (mode=off)
        # are both answerable from session.metrics() alone
        yield "trace_spans_dropped_total", {}, st["dropped"]
        mode_code = {"off": 0, "sampled": 1, "full": 2}.get(st["mode"], -1)
        yield "trace_mode", {"mode": st["mode"]}, mode_code


def _register_process_collectors(
        registry: "_obs_metrics.MetricsRegistry") -> None:
    if getattr(registry, "_process_collectors", None) is None:
        anchor = _ProcessCollectors()
        registry._process_collectors = anchor        # keeps weakref alive
        registry.register_callback(anchor, _ProcessCollectors.samples)
