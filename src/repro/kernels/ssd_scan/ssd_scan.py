"""Chunked SSD (Mamba-2) Pallas TPU kernel.

State-space duality: within a chunk of length L, the output is a masked
quadratic form (MXU matmuls); across chunks an (P, N) state is carried
sequentially.  Grid: (B, H, nc) with the chunk dimension sequential and the
state living in VMEM scratch — the TPU-native layout for SSD: chunk-local
matmuls hit the MXU, the O(T/L) carry is the only sequential dependency.

Per-step VMEM working set (L=256, P=64, N=128 fp32):
    x (L,P) + B,C (L,N) + decay (L,L) + state (P,N)  ≈ 0.6 MB.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (L,)
    A = a_ref[0].astype(jnp.float32)             # scalar (per head)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (L, N)

    dA = dt * A                                  # (L,)
    cs = jnp.cumsum(dA)                          # (L,)

    # intra-chunk: y_diag = tril(C Bᵀ ⊙ exp(segsum)) · (dt ⊙ x)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (L,L)
    seg = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(lj <= li, jnp.exp(seg), 0.0)
    W = scores * decay * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())))          # (L,P)

    # inter-chunk: y += (C · stateᵀ) ⊙ exp(cs)
    y += jax.lax.dot_general(Cm, state_ref[...],
                             (((1,), (1,)), ((), ()))) * jnp.exp(cs)[:, None]

    # state ← state·exp(cs[-1]) + xᵀ · (B ⊙ (exp(cs[-1]-cs)·dt))
    w_state = (jnp.exp(cs[-1] - cs) * dt)[:, None] * Bm              # (L,N)
    upd = jax.lax.dot_general(x, w_state, (((0,), (0,)), ((), ())))  # (P,N)
    state_ref[...] = state_ref[...] * jnp.exp(cs[-1]) + upd

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int, *,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N) — B/C shared
    across heads (single SSM group).  Returns (y (B,T,H,P), state (B,H,P,N))."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, "pad T to a chunk multiple first"
    nc = T // chunk

    # kernel-native layouts
    xk = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, chunk, P)
    dtk = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, chunk)
    bk = Bm.reshape(Bsz, nc, chunk, N)
    ck = Cm.reshape(Bsz, nc, chunk, N)

    kernel = functools.partial(_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P),
                         lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P),
                         lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xk, dtk, A, bk, ck)
    y = y.reshape(Bsz, H, T, P).transpose(0, 2, 1, 3)
    return y, state
