"""Pure-jnp oracle for the chunked SSD kernel — reuses the model's
reference implementation (models/ssd.ssd_scan_ref)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from ...models.ssd import ssd_scan_ref


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, chunk: int,
            init_state: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N)."""
    return ssd_scan_ref(x, dt, A, Bm, Cm, chunk, init_state)
