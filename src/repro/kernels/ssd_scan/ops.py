"""Jitted wrapper for the SSD kernel (oracle fallback off-TPU)."""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from .ref import ssd_ref
from .ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = False,
        use_kernel: bool = True) -> Tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return ssd_ref(x, dt, A, Bm, Cm, chunk)
    return ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=interpret)
