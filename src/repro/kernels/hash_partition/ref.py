"""Pure-jnp oracle for the fused hash-partition kernel."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wang_hash(x: jax.Array) -> jax.Array:
    """Deterministic 32-bit integer mix (matches core.ir._mix_hash)."""
    x = x.astype(jnp.uint32)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def hash_partition_ref(keys: jax.Array,
                       num_partitions: int) -> Tuple[jax.Array, jax.Array]:
    """keys: (N,) int32/uint32 → (pids (N,) int32, counts (m,) int32).

    ``g_hh(d) = hash(f(d)) % m`` + the per-partition histogram the store
    needs to size its buffers — the paper's storage-time dispatch."""
    pids = (wang_hash(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)
    counts = jnp.bincount(pids, length=num_partitions).astype(jnp.int32)
    return pids, counts
