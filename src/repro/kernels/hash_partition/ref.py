"""Pure-jnp oracle for the fused hash-partition kernel."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wang_hash(x: jax.Array) -> jax.Array:
    """Deterministic 32-bit integer mix (matches core.ir._mix_hash)."""
    x = x.astype(jnp.uint32)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def hash_partition_ref(keys: jax.Array,
                       num_partitions: int) -> Tuple[jax.Array, jax.Array]:
    """keys: (N,) int32/uint32 → (pids (N,) int32, counts (m,) int32).

    ``g_hh(d) = hash(f(d)) % m`` + the per-partition histogram the store
    needs to size its buffers — the paper's storage-time dispatch."""
    pids = (wang_hash(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)
    counts = jnp.bincount(pids, length=num_partitions).astype(jnp.int32)
    return pids, counts


def hash_partition_padded_ref(keys: jax.Array, n_valid: jax.Array,
                              num_partitions: int
                              ) -> Tuple[jax.Array, jax.Array]:
    """Dynamic-``n`` oracle for shape-bucketed dispatch plans.

    ``keys`` is padded to a bucket size B ≥ n_valid; padding rows land in an
    overflow partition ``m`` so downstream counting sort places them past the
    valid region.  Returns (pids (B,) int32 with padding → m,
    counts (m+1,) int32 where counts[m] = B - n_valid).
    """
    B = keys.shape[0]
    pid = (wang_hash(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)
    valid = jnp.arange(B, dtype=jnp.int32) < n_valid
    pids = jnp.where(valid, pid, num_partitions)
    counts = jnp.zeros(num_partitions + 1, jnp.int32).at[pids].add(1)
    return pids, counts


def scatter_perm_ref(pids: jax.Array,
                     counts: jax.Array = None) -> jax.Array:
    """Oracle for the counting-sort scatter: destination permutation.

    ``dest[i]`` is row i's position in the *stable* sort of ``pids`` — i.e.
    the inverse of ``argsort(pids, stable=True)``, which is exactly what the
    O(N) counting-sort kernel emits (``counts`` is ignored here; the kernel
    needs it to seed its offsets, the oracle recovers it from the sort).
    """
    n = pids.shape[0]
    order = jnp.argsort(pids, stable=True)
    return jnp.zeros(n, jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
