"""Fused hash-partition Pallas TPU kernel — the paper's dispatch hot spot.

Storage-time partitioning (Alg. 3 line 13-14) is a streaming pass over every
object: hash the partition key, take ``% m``, and histogram the destinations
so the store can size per-partition buffers.  Fusing hash + mod + histogram
into one VMEM-resident pass makes the producer-side overhead (paper Tab. 3:
≤10%) bandwidth-bound rather than kernel-launch-bound.

Tiling: grid over key blocks; each step hashes a (block,) tile in VMEM,
emits pids, and accumulates a private (m,) histogram in VMEM scratch that
is flushed once at the end (grid dim is sequential on TPU, so the scratch
carries across steps).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK = 2048


def _kernel(keys_ref, pids_ref, counts_ref, hist_ref, *,
            num_partitions: int, block: int, n_valid: int):
    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = keys_ref[...].astype(jnp.uint32)
    # Wang hash (matches ref.wang_hash / core.ir._mix_hash)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    pid = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    pids_ref[...] = pid

    # mask padding tail so it never lands in the histogram
    pos = i * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = pos < n_valid
    onehot = (pid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, num_partitions), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    hist_ref[...] += onehot.astype(jnp.int32).sum(axis=0)

    @pl.when(i == nb - 1)
    def _flush():
        counts_ref[...] = hist_ref[...]


def hash_partition(keys: jax.Array, num_partitions: int, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """keys: (N,) integer → (pids (N,) int32, counts (m,) int32)."""
    n = keys.shape[0]
    block = min(block, max(8, n))
    pad = (-n) % block
    if pad:
        keys = jnp.pad(keys, (0, pad))
    nb = keys.shape[0] // block

    kernel = functools.partial(_kernel, num_partitions=num_partitions,
                               block=block, n_valid=n)
    pids, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((num_partitions,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((num_partitions,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((num_partitions,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(keys)
    return pids[:n], counts
