"""Fused hash-partition Pallas TPU kernels — the paper's dispatch hot spot.

Storage-time partitioning (Alg. 3 line 13-14) is a streaming pass over every
object: hash the partition key, take ``% m``, and histogram the destinations
so the store can size per-partition buffers.  Fusing hash + mod + histogram
into one VMEM-resident pass makes the producer-side overhead (paper Tab. 3:
≤10%) bandwidth-bound rather than kernel-launch-bound.

Three kernels (DESIGN §5):

* :func:`hash_partition` — hash + mod + histogram over exactly-sized keys
  (``n`` static; padding tail masked out of the histogram).
* :func:`hash_partition_padded` — the same pass over a shape-bucketed buffer
  with a *dynamic* valid count delivered via scalar prefetch; padding rows
  are assigned an overflow partition ``m`` so the counting sort places them
  past the valid region.  This is what lets one jitted dispatch plan serve
  every N in a shape bucket without retracing.
* :func:`scatter_perm` — the counting-sort scatter stage: consume
  ``(pids, counts)``, compute per-partition offsets with an in-kernel
  exclusive prefix sum, and emit the destination permutation directly —
  an O(N) *stable* placement replacing the O(N log N) ``argsort`` the
  re-bucket used to pay.

Tiling: grid over key blocks; each step processes a (block,) tile in VMEM
and carries per-partition state ((m,) histogram / running offsets) in VMEM
scratch across steps (the grid dim is sequential on TPU).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK = 2048


def _wang(x):
    """Wang hash (matches ref.wang_hash / core.ir._mix_hash)."""
    x = x.astype(jnp.uint32)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def _kernel(keys_ref, pids_ref, counts_ref, hist_ref, *,
            num_partitions: int, block: int, n_valid: int):
    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = _wang(keys_ref[...])
    pid = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    pids_ref[...] = pid

    # mask padding tail so it never lands in the histogram
    pos = i * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = pos < n_valid
    onehot = (pid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, num_partitions), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    hist_ref[...] += onehot.astype(jnp.int32).sum(axis=0)

    @pl.when(i == nb - 1)
    def _flush():
        counts_ref[...] = hist_ref[...]


def hash_partition(keys: jax.Array, num_partitions: int, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """keys: (N,) integer → (pids (N,) int32, counts (m,) int32)."""
    n = keys.shape[0]
    block = min(block, max(8, n))
    pad = (-n) % block
    if pad:
        keys = jnp.pad(keys, (0, pad))
    nb = keys.shape[0] // block

    kernel = functools.partial(_kernel, num_partitions=num_partitions,
                               block=block, n_valid=n)
    pids, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((num_partitions,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((num_partitions,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((num_partitions,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(keys)
    return pids[:n], counts


# ---------------------------------------------------------------------------
# Dynamic-n variant: shape-bucketed keys + scalar-prefetched valid count
# ---------------------------------------------------------------------------

def _kernel_padded(n_ref, keys_ref, pids_ref, counts_ref, hist_ref, *,
                   num_partitions: int, block: int):
    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = _wang(keys_ref[...])
    pid_raw = (x % jnp.uint32(num_partitions)).astype(jnp.int32)
    # padding rows → overflow partition m, so the counting sort that consumes
    # these pids stably parks them *after* every valid row
    pos = i * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    pid = jnp.where(pos < n_ref[0], pid_raw, num_partitions)
    pids_ref[...] = pid

    onehot = (pid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, num_partitions + 1), 1))
    hist_ref[...] += onehot.astype(jnp.int32).sum(axis=0)

    @pl.when(i == nb - 1)
    def _flush():
        counts_ref[...] = hist_ref[...]


def hash_partition_padded(keys: jax.Array, n_valid: jax.Array,
                          num_partitions: int, *,
                          block: int = DEFAULT_BLOCK,
                          interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    """keys: (B,) integer, n_valid: () int32 dynamic →
    (pids (B,) int32 with padding → m, counts (m+1,) int32).

    B must already be a multiple-friendly bucket size (the caller pads); the
    valid count arrives via scalar prefetch so one compiled plan serves every
    N ≤ B without retracing.
    """
    B = keys.shape[0]
    block = min(block, max(8, B))
    assert B % block == 0, "block size must divide the bucketed key count"
    nb = B // block
    m1 = num_partitions + 1

    kernel = functools.partial(_kernel_padded, num_partitions=num_partitions,
                               block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i, n_ref: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i, n_ref: (i,)),
                   pl.BlockSpec((m1,), lambda i, n_ref: (0,))],
        scratch_shapes=[pltpu.VMEM((m1,), jnp.int32)],
    )
    pids, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((m1,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), keys)
    return pids, counts


# ---------------------------------------------------------------------------
# Counting-sort scatter: (pids, counts) → destination permutation, O(N)
# ---------------------------------------------------------------------------

def _perm_kernel(pids_ref, counts_ref, dest_ref, offs_ref, *,
                 num_partitions: int, block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # in-kernel exclusive prefix sum of the histogram → base offsets
        c = counts_ref[...]
        offs_ref[...] = jnp.cumsum(c) - c

    pid = pids_ref[...]                                    # (block,) int32
    onehot = (pid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, num_partitions), 1))
    oh = onehot.astype(jnp.int32)
    # stable within-block rank of each row among same-pid rows
    rank = ((jnp.cumsum(oh, axis=0) - oh) * oh).sum(axis=1)
    base = (offs_ref[...][None, :] * oh).sum(axis=1)
    dest_ref[...] = base + rank
    # carry: partitions already filled by this block
    offs_ref[...] += oh.sum(axis=0)


def scatter_perm(pids: jax.Array, counts: jax.Array, *,
                 block: int = DEFAULT_BLOCK,
                 interpret: bool = False) -> jax.Array:
    """(pids (N,) int32, counts (m,) int32) → dest (N,) int32.

    ``dest[i]`` = position of row i in the stable sort of ``pids`` — the
    counting-sort placement (base offset from the in-kernel prefix sum +
    running per-partition fill + within-block stable rank).  O(N·m/VPU)
    with no sort; sentinel pids outside [0, m) get garbage dests without
    perturbing any real row's slot (their one-hot row is all-False).
    """
    n = pids.shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32)
    m = counts.shape[0]
    block = min(block, max(8, n))
    pad = (-n) % block
    if pad:                       # sentinel never matches a real partition
        pids = jnp.pad(pids, (0, pad), constant_values=-1)
    nb = pids.shape[0] // block

    kernel = functools.partial(_perm_kernel, num_partitions=m, block=block)
    dest = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((m,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pids.shape[0],), jnp.int32),
        scratch_shapes=[pltpu.VMEM((m,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(pids.astype(jnp.int32), counts.astype(jnp.int32))
    return dest[:n]
