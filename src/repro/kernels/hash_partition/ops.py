"""Jitted wrappers for the hash-partition kernel family.

Each wrapper jits once per static config and dispatches to the Pallas
kernel (``use_kernel=True`` — compiled on TPU, interpret elsewhere) or the
pure-jnp oracle.  The oracle and kernel are bit-identical (tested), so the
dispatch-plan layer in ``data/device_repartition.py`` picks whichever is
actually fast on the active backend.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from .hash_partition import (hash_partition, hash_partition_padded,
                             scatter_perm)
from .ref import (hash_partition_padded_ref, hash_partition_ref,
                  scatter_perm_ref)


@partial(jax.jit, static_argnames=("num_partitions", "interpret",
                                   "use_kernel"))
def partition_ids(keys, num_partitions: int, *, interpret: bool = False,
                  use_kernel: bool = True) -> Tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return hash_partition_ref(keys, num_partitions)
    return hash_partition(keys, num_partitions, interpret=interpret)


@partial(jax.jit, static_argnames=("num_partitions", "interpret",
                                   "use_kernel"))
def padded_partition_ids(keys, n_valid, num_partitions: int, *,
                         interpret: bool = False, use_kernel: bool = True
                         ) -> Tuple[jax.Array, jax.Array]:
    """Shape-bucketed dispatch: keys (B,) + dynamic valid count →
    (pids (B,) with padding → m, counts (m+1,))."""
    if not use_kernel:
        return hash_partition_padded_ref(keys, n_valid, num_partitions)
    return hash_partition_padded(keys, n_valid, num_partitions,
                                 interpret=interpret)


@partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def scatter_permutation(pids, counts, *, interpret: bool = False,
                        use_kernel: bool = True) -> jax.Array:
    """Counting-sort destination permutation: (pids, matching histogram) →
    dest (N,) int32, the stable O(N) replacement for
    ``argsort(pids, stable=True)`` + inversion."""
    if not use_kernel:
        return scatter_perm_ref(pids, counts)
    return scatter_perm(pids, counts, interpret=interpret)
