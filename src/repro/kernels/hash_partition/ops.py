"""Jitted wrapper for the hash-partition kernel (falls back to the oracle
off-TPU; the PartitionStore calls this at storage time)."""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from .hash_partition import hash_partition
from .ref import hash_partition_ref


@partial(jax.jit, static_argnames=("num_partitions", "interpret",
                                   "use_kernel"))
def partition_ids(keys, num_partitions: int, *, interpret: bool = False,
                  use_kernel: bool = True) -> Tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return hash_partition_ref(keys, num_partitions)
    return hash_partition(keys, num_partitions, interpret=interpret)
