"""Jitted public wrapper for the flash-attention kernel.

On TPU this dispatches to the Pallas kernel; everywhere else (this CPU
container) it validates through ``interpret=True`` or falls back to the
pure-jnp oracle.  The model layers call ``layers.auto_sdpa`` (the jnp
blockwise path); serving/training on real TPUs flips ``use_kernel=True``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret",
                                   "use_kernel"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, softcap: float = 0.0,
              scale: Optional[float] = None, block_q: int = 512,
              block_k: int = 512, interpret: bool = False,
              use_kernel: bool = True):
    """q: (B,H,S,hd); k/v: (B,KV,S,hd)."""
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, block_q=block_q,
                           block_k=block_k, interpret=interpret)
