"""Pure-jnp oracle for the flash-attention kernel.

Layout (B, H, S, hd) — kernel-native.  GQA: KV heads broadcast by group.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: float = 0.0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Skv,hd); KV divides H."""
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
