"""Flash attention Pallas TPU kernel.

Canonical online-softmax formulation: grid (B, H, nq, nk) with the kv-block
dimension innermost/sequential; running max ``m``, denominator ``l`` and the
output accumulator live in VMEM scratch and are carried across kv blocks.
GQA is handled *in the index map* (kv head = h // group), so K/V are never
materialized per-query-head.

BlockSpec tiling (VMEM working set per step):
    q tile  (1, 1, block_q, hd)
    k tile  (1, 1, block_k, hd)
    v tile  (1, 1, block_k, hd)
    acc     (block_q, hd) f32 + m/l (block_q,) f32
With block_q = block_k = 512 and hd = 128: ~1.5 MB — far under the ~16 MB
v5e VMEM, and all matmul dims are multiples of 128 (MXU-aligned).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: float, block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # skip fully-masked tiles (causal: kv block strictly after the q block)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < kv_len                  # masks kv padding tail
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd); KV divides H."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded kv positions are masked off via window/causal iota masks
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = q.shape[2], k.shape[2]
    nq, nk = Sq_p // block_q, Skv_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
