"""Gradient compression for the DP all-reduce (distributed-optimization trick).

Two schemes, both with error feedback so compression error is re-injected on
the next step (keeps convergence):

* int8 uniform quantization  — 4× fewer bytes on the wire
* top-k sparsification       — send the k largest-|g| entries per tensor

Compression runs *before* the data-parallel reduction: on real hardware the
psum would operate on the compressed representation (int8 payload / sparse
(idx, val) pairs).  In the lowered single-program view we expose
``compress → decompress`` as a pluggable reducer transform; the roofline
collective term records the reduced byte count.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any     # pytree matching grads


def init_error_feedback(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                              grads_like))


# -- int8 quantization -------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8(grads: Any, ef: ErrorFeedbackState):
    """Returns (decompressed grads, new EF state, wire_bytes)."""
    wire_bytes = 0

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    wire_bytes = sum(int(g.size) * 1 + 4 for g in flat_g)   # int8 + scale
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, ErrorFeedbackState(new_r), wire_bytes


# -- top-k sparsification ------------------------------------------------------

def compress_topk(grads: Any, ef: ErrorFeedbackState, frac: float = 0.05):
    """Keep top-|g| ``frac`` of entries per tensor; rest go to the residual."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        flat = x.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(x) >= thresh).astype(jnp.float32)
        kept = x * mask
        return kept.astype(g.dtype), x - kept, k

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    wire_bytes = sum(o[2] * 8 for o in outs)   # (int32 idx, fp32 val) pairs
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, ErrorFeedbackState(new_r), wire_bytes
