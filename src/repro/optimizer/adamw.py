"""AdamW over arbitrary pytrees, with ZeRO-style state sharding hooks.

No optax in this environment — this is the substrate implementation.
State layout mirrors the param pytree: ``m`` and ``v`` trees plus a step
counter.  ``state_dtype`` lets very large models (the 400B MoE) keep moments
in bf16 so the optimizer state fits the per-chip HBM budget; the update math
is always performed in fp32.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Any = 1e-3                    # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: Optional[jnp.dtype] = None   # None → same as param dtype

    def init(self, params: Any) -> AdamWState:
        def zeros_like(p):
            dt = self.state_dtype or p.dtype
            return jnp.zeros(p.shape, dtype=dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros_like, params),
                          v=jax.tree.map(zeros_like, params))

    def update(self, grads: Any, state: AdamWState, params: Any):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        new = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([t[0] for t in new])
        new_m = treedef.unflatten([t[1] for t in new])
        new_v = treedef.unflatten([t[2] for t in new])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def sgd_update(grads, params, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
