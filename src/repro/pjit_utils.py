"""SPMD helpers usable from model code without importing launch/.

``constrain`` applies an internal sharding constraint only when the process
has opted into SPMD mode (dry-run / distributed training); smoke tests and
single-device benches run with constraints disabled so no mesh is required.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_SPMD = False


def enable_spmd(flag: bool = True) -> None:
    global _SPMD
    _SPMD = flag


def spmd_enabled() -> bool:
    return _SPMD


def constrain(x, spec: P):
    if _SPMD:
        return jax.lax.with_sharding_constraint(x, spec)
    return x
