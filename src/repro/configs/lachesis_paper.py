"""The paper's own workload suite as a selectable config (DESIGN §7).

Not an LM architecture: Lachesis's native "models" are UDF analytics
workflows.  This config bundles the canned DSL workloads (§5.1) with their
datasets so drivers/benchmarks can iterate over them uniformly.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

from ..core import dsl


@dataclass(frozen=True)
class PaperWorkloadConfig:
    name: str = "lachesis-paper-suite"
    workflows: Tuple[Tuple[str, Callable], ...] = (
        ("reddit_integration", dsl.author_integrator),
        ("pagerank_iteration", dsl.pagerank_iteration),
        ("block_matmul", dsl.matmul_workload),
        ("gram_matrix", lambda: dsl.matmul_workload(transpose_left=True)),
    )
    # paper §5.1 cluster points used for the modeled-network numbers
    clusters: Tuple[Tuple[str, int, float], ...] = (
        ("aws-5w-10gbps", 5, 1.25e9),
        ("aws-10w-10gbps", 10, 1.25e9),
        ("aws-10w-1gbps", 10, 0.125e9),
        ("gcp-8w-10gbps", 8, 1.25e9),
    )
    # Repartition backends benchmarked against each other (DESIGN §5):
    # "host" = numpy gather/re-bucket, "device" = Pallas hash_partition
    # kernel + jax scatter (interpret mode off-TPU).  Consumed by
    # benchmarks/bench_overhead.repartition_backends.
    engine_backends: Tuple[str, ...] = ("host", "device")


def get() -> PaperWorkloadConfig:
    return PaperWorkloadConfig()
