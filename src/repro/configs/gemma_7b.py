"""gemma-7b — dense GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L, d_model=3072, 16H (kv=16 ⇒ MHA; 2b sibling uses MQA), head_dim=256
(q-dim 4096 > d_model), d_ff=24576 GeGLU, vocab=256000, embeddings
scaled by sqrt(d_model).  Pure full attention ⇒ long_500k skipped."""

from .base import ArchConfig, LayerSpec, register


@register("gemma-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        pattern=(LayerSpec(mixer="attn", attn_kind="global", ffn="dense"),),
        ffn_activation="gelu", embed_scale=True, tie_embeddings=True,
        subquadratic=False,
        accum_steps=2,
    )
