"""gemma2-27b — dense, local/global alternating, softcaps
[arXiv:2408.00118; hf].

46L, d_model=4608, 32H (GQA kv=16, head_dim=128), d_ff=36864 (GeGLU),
vocab=256000.  Pattern: (local 4096-window, global) alternating; attn
softcap 50, final logit softcap 30; pre+post norms; query scale
1/sqrt(query_pre_attn_scalar=144).  Local layers make decode sub-linear
in cache reads ⇒ long_500k runs (global layers read the full cache)."""

from .base import ArchConfig, LayerSpec, register


@register("gemma2-27b")
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b", family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        pattern=(LayerSpec(mixer="attn", attn_kind="local", ffn="dense"),
                 LayerSpec(mixer="attn", attn_kind="global", ffn="dense")),
        ffn_activation="gelu", sliding_window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        attn_scale=144.0 ** -0.5, use_post_norm=True,
        embed_scale=True, tie_embeddings=True,
        subquadratic=True,
        accum_steps=4,
    )
