"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf].

24L, d_model=2048, 16H (GQA kv=8, head_dim=128), d_ff=8192,
vocab=92544.  Pure full attention ⇒ long_500k skipped."""

from .base import ArchConfig, LayerSpec, register


@register("internlm2-1.8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-1.8b", family="dense",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=92544,
        pattern=(LayerSpec(mixer="attn", attn_kind="global", ffn="dense"),),
        rope_theta=1000000.0, tie_embeddings=False, subquadratic=False,
    )
