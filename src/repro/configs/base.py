"""Architecture config schema + registry.

Every assigned architecture is an :class:`ArchConfig`; the layer stack is a
cyclic ``pattern`` of :class:`LayerSpec`s (period p), scanned over
``num_layers // p`` groups with the remainder unrolled — this keeps compile
time flat in depth while supporting alternating-layer archs (gemma2
local/global, recurrentgemma 2:1 recurrent:attention, llama4 iRoPE+MoE).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple

_REGISTRY: Dict[str, Callable[[], "ArchConfig"]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> "ArchConfig":
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # attn | mla | ssd | rglru
    attn_kind: str = "global"    # global | local
    use_rope: bool = True        # False → NoPE layer (llama4 global layers)
    ffn: str = "dense"           # dense | moe | none


@dataclass(frozen=True)
class MoEParams:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAParams:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSDParams:
    d_inner: int
    state: int = 128
    nheads: int = 32
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class RGLRUParams:
    width: int
    conv_width: int = 4


@dataclass(frozen=True)
class EncoderParams:
    num_layers: int
    num_frames: int = 1500       # whisper 30 s @ 50 Hz
    d_ff: int = 3072


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: Tuple[LayerSpec, ...] = ()     # unrolled layers before the scan
    # attention details
    ffn_activation: str = "silu"
    ffn_gated: bool = True                 # False → plain MLP (whisper)
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    sliding_window: int = 4096
    rope_theta: float = 10000.0
    attn_scale: Optional[float] = None     # gemma2 query_pre_attn_scalar
    positional: str = "rope"               # rope | learned | none
    max_learned_pos: int = 32768
    # optional sub-configs
    moe: Optional[MoEParams] = None
    mla: Optional[MLAParams] = None
    ssd: Optional[SSDParams] = None
    rglru: Optional[RGLRUParams] = None
    encoder: Optional[EncoderParams] = None
    frontend: str = "none"                 # none | audio | vq
    # misc
    norm: str = "rmsnorm"
    use_post_norm: bool = False            # gemma2 pre+post norms
    tie_embeddings: bool = True
    embed_scale: bool = False              # gemma: × sqrt(d_model)
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"             # full | dots (save matmul outputs)
    mla_absorbed: bool = False             # score in latent space (no K expand)
    subquadratic: bool = False             # supports long_500k
    # training batch/microbatch knobs (overridable per run)
    accum_steps: int = 1
    # optimizer memory: bf16 moments for very large models
    opt_state_bf16: bool = False
    # optimized decode: local layers keep only a window-sized cache
    windowed_local_cache: bool = False

    # -- derived ----------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def pattern_groups(self) -> int:
        return (self.num_layers - len(self.prefix)) // len(self.pattern)

    @property
    def tail_specs(self) -> Tuple[LayerSpec, ...]:
        r = (self.num_layers - len(self.prefix)) % len(self.pattern)
        return self.pattern[:r]

    @property
    def all_specs(self) -> Tuple[LayerSpec, ...]:
        return (tuple(self.prefix)
                + tuple(self.pattern) * self.pattern_groups
                + tuple(self.tail_specs))

    def param_count(self) -> int:
        """Analytic N (total) — used for 6·N·D roofline checks."""
        D, H, KV, hd, F = (self.d_model, self.num_heads, self.num_kv_heads,
                           self.head_dim, self.d_ff)
        total = self.padded_vocab * D            # embed (tied unembed)
        if not self.tie_embeddings:
            total += self.padded_vocab * D
        for s in self.all_specs:
            if s.mixer == "attn":
                total += D * H * hd + 2 * D * KV * hd + H * hd * D
            elif s.mixer == "mla":
                m = self.mla
                total += (D * m.q_lora_rank
                          + m.q_lora_rank * H * (m.nope_head_dim + m.rope_head_dim)
                          + D * (m.kv_lora_rank + m.rope_head_dim)
                          + m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
                          + H * m.v_head_dim * D)
            elif s.mixer == "ssd":
                sd = self.ssd
                total += (D * (2 * sd.d_inner + 2 * sd.state + sd.nheads)
                          + sd.d_inner * D)
            elif s.mixer == "rglru":
                r = self.rglru
                total += 2 * D * r.width + 2 * r.width ** 2 + r.width * D
            if s.ffn == "dense":
                total += (3 if self.ffn_gated else 2) * D * F
            elif s.ffn == "moe":
                m = self.moe
                total += m.num_experts * 3 * D * m.d_ff_expert + D * m.num_experts
                if m.num_shared:
                    total += 3 * D * m.d_ff_expert * m.num_shared
        if self.encoder:
            e = self.encoder
            total += e.num_layers * (4 * D * H * hd + 2 * D * e.d_ff)
            # decoder cross-attention
            total += self.num_layers * 4 * D * H * hd
        return total

    def active_param_count(self) -> int:
        """N_active for MoE rooflines (6·N_active·D)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_total = self.param_count()
        n_moe = sum(1 for s in self.all_specs if s.ffn == "moe")
        all_expert = n_moe * m.num_experts * 3 * self.d_model * m.d_ff_expert
        active_expert = n_moe * m.top_k * 3 * self.d_model * m.d_ff_expert
        return dense_total - all_expert + active_expert


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM arch
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig):
    """The (arch × shape) cells this arch runs; long_500k only when
    sub-quadratic (see DESIGN.md §4 skip table)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]
