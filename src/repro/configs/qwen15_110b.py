"""qwen1.5-110b — dense GQA with QKV bias [hf:Qwen/Qwen1.5 family].

80L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=49152,
vocab=152064, QKV bias, untied embeddings.  Largest dense arch in the
pool — the collective-bound hillclimb target.  Pure full attention ⇒
long_500k skipped."""

from .base import ArchConfig, LayerSpec, register


@register("qwen1.5-110b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=49152, vocab_size=152064,
        pattern=(LayerSpec(mixer="attn", attn_kind="global", ffn="dense"),),
        qkv_bias=True, rope_theta=1000000.0,
        tie_embeddings=False, subquadratic=False,
        opt_state_bf16=True,
        accum_steps=8,
    )
