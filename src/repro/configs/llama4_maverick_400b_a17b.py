"""llama4-maverick-400b-a17b — MoE, early fusion
[hf:meta-llama/Llama-4-* family; unverified].

48L, d_model=5120, 40H (GQA kv=8, head_dim=128), d_ff=8192,
vocab=202048, MoE 128 experts top-1 (+1 shared), interleaved every other
layer (Maverick-style).  iRoPE: 3 chunked-local RoPE layers : 1 global
NoPE layer (period 4, lcm with the MoE period).  Chunked-local window
8192 ⇒ sub-quadratic local layers; global layers decode against the full
cache — long_500k runs (decode is per-token linear).  bf16 optimizer
moments so state fits per-chip HBM at 400B."""

from .base import ArchConfig, LayerSpec, MoEParams, register


@register("llama4-maverick-400b-a17b")
def config() -> ArchConfig:
    loc, glob = "local", "global"
    return ArchConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        pattern=(
            LayerSpec(mixer="attn", attn_kind=loc, use_rope=True, ffn="dense"),
            LayerSpec(mixer="attn", attn_kind=loc, use_rope=True, ffn="moe"),
            LayerSpec(mixer="attn", attn_kind=loc, use_rope=True, ffn="dense"),
            LayerSpec(mixer="attn", attn_kind=glob, use_rope=False, ffn="moe"),
        ),
        moe=MoEParams(num_experts=128, top_k=1, d_ff_expert=8192,
                      num_shared=1),
        sliding_window=8192, rope_theta=500000.0,
        frontend="vq",                       # early-fusion stub
        tie_embeddings=False, subquadratic=True,
        opt_state_bf16=True,
        accum_steps=4,
    )
