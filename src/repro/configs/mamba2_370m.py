"""mamba2-370m — attention-free SSM via SSD [arXiv:2405.21060; unverified].

48L, d_model=1024, Mamba-2 blocks only (d_ff=0: no separate FFN),
d_inner=2048, ssm_state=128, 32 heads (headdim 64), conv width 4,
chunk 256, vocab=50280.  No positional encoding; the recurrence carries
position.  State is O(H·P·N) per layer, no KV cache ⇒ long_500k runs.
Lachesis §Arch-applicability: keyed-join partitioning is inapplicable
(attention-free, no dispatch shuffle); data/batch-layout advice applies."""

from .base import ArchConfig, LayerSpec, SSDParams, register


@register("mamba2-370m")
def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=0, vocab_size=50280,
        pattern=(LayerSpec(mixer="ssd", ffn="none"),),
        ssd=SSDParams(d_inner=2048, state=128, nheads=32,
                      conv_width=4, chunk=256),
        positional="none", tie_embeddings=True,
        subquadratic=True,
    )
