"""whisper-small — enc-dec audio backbone [arXiv:2212.04356; unverified].

12L decoder + 12L encoder, d_model=768, 12H (MHA, kv=12, head_dim=64),
d_ff=3072, vocab=51865.  Conv audio frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 768).  Plain (non-gated)
GELU MLP, LayerNorm, learned decoder positions, sinusoidal encoder
positions.  Full attention ⇒ long_500k skipped (DESIGN §4)."""

from .base import ArchConfig, EncoderParams, LayerSpec, register


@register("whisper-small")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small", family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=51865,
        pattern=(LayerSpec(mixer="attn", attn_kind="global",
                           use_rope=False, ffn="dense"),),
        ffn_activation="gelu", ffn_gated=False,
        positional="learned", norm="layernorm",
        encoder=EncoderParams(num_layers=12, num_frames=1500, d_ff=3072),
        frontend="audio", tie_embeddings=True,
        subquadratic=False,
    )
