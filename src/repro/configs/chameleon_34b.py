"""chameleon-34b — early-fusion VLM [arXiv:2405.09818; unverified].

48L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=22016,
vocab=65536 (text + VQ image codes in one early-fused stream).  QK-norm
(chameleon's stabilization).  The VQ tokenizer is a STUB: input_specs()
provides the fused token ids directly.  Pure full attention ⇒ long_500k
skipped."""

from .base import ArchConfig, LayerSpec, register


@register("chameleon-34b")
def config() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=65536,
        pattern=(LayerSpec(mixer="attn", attn_kind="global", ffn="dense"),),
        qk_norm=True, frontend="vq",
        tie_embeddings=False, subquadratic=False,
        opt_state_bf16=True,
        accum_steps=4,
    )
