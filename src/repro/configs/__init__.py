"""Arch config registry: one module per assigned architecture."""
from .base import (ArchConfig, LayerSpec, ShapeSpec, SHAPES, get_config,
                   list_archs, shapes_for)
from . import (whisper_small, llama4_maverick_400b_a17b, deepseek_v2_236b,
               gemma2_27b, gemma_7b, qwen15_110b, internlm2_1_8b,
               chameleon_34b, recurrentgemma_9b, mamba2_370m)
