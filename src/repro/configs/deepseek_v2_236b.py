"""deepseek-v2-236b — MoE with Multi-head Latent Attention
[arXiv:2405.04434; hf].

60L, d_model=5120, 128H MLA (kv_lora_rank=512, q_lora=1536, nope=128,
rope=64, v=128), vocab=102400.  First layer dense FFN d_ff=12288; the
remaining 59 layers are MoE: 160 routed experts top-6 (d_ff_expert=1536)
+ 2 shared experts.  Full attention ⇒ long_500k skipped; the MLA
compressed KV cache (512+64 per token vs 2·128·128) is the decode story."""

from .base import ArchConfig, LayerSpec, MLAParams, MoEParams, register


@register("deepseek-v2-236b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        head_dim=128, d_ff=12288, vocab_size=102400,
        prefix=(LayerSpec(mixer="mla", ffn="dense"),),
        pattern=(LayerSpec(mixer="mla", ffn="moe"),),
        mla=MLAParams(kv_lora_rank=512, q_lora_rank=1536,
                      nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
        moe=MoEParams(num_experts=160, top_k=6, d_ff_expert=1536,
                      num_shared=2),
        tie_embeddings=False, subquadratic=False,
        opt_state_bf16=True,
        accum_steps=4,
    )
