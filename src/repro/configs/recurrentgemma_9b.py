"""recurrentgemma-9b — RG-LRU + local attention hybrid (Griffin)
[arXiv:2402.19427; unverified].

38L, d_model=4096, pattern 2 recurrent : 1 local-attention (period 3,
12 groups + 2-layer recurrent tail), 16H MQA (kv=1, head_dim=256) on the
attention layers, d_ff=12288 GeGLU, rglru width 4096, local window 2048,
vocab=256000.  Recurrent state is O(width) and local KV is window-bounded
⇒ long_500k runs natively."""

from .base import ArchConfig, LayerSpec, RGLRUParams, register


@register("recurrentgemma-9b")
def config() -> ArchConfig:
    rec = LayerSpec(mixer="rglru", ffn="dense")
    att = LayerSpec(mixer="attn", attn_kind="local", ffn="dense")
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        pattern=(rec, rec, att),
        rglru=RGLRUParams(width=4096, conv_width=4),
        ffn_activation="gelu", sliding_window=2048,
        embed_scale=True, tie_embeddings=True,
        subquadratic=True, windowed_local_cache=True,
        accum_steps=4,
    )
