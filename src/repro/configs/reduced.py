"""Reduced configs for CPU smoke tests (same family/structure, tiny dims).

Every assigned arch gets a shrunken sibling: identical pattern/prefix/tail
structure and mixer kinds, but small widths, few experts, tiny vocab — so a
forward/train step runs on one CPU in seconds while exercising the exact
code paths the full config lowers through.
"""

from __future__ import annotations

from dataclasses import replace

from .base import (ArchConfig, EncoderParams, MLAParams, MoEParams,
                   RGLRUParams, SSDParams)


def reduced(cfg: ArchConfig) -> ArchConfig:
    p = len(cfg.pattern)
    # keep prefix + 2 pattern groups + (tail if the arch has one)
    tail = len(cfg.tail_specs)
    num_layers = len(cfg.prefix) + 2 * p + tail

    if cfg.num_kv_heads == cfg.num_heads:
        kv = 4
    elif cfg.num_kv_heads == 1:
        kv = 1
    else:
        kv = 2
    kw = dict(
        num_layers=num_layers, d_model=64, num_heads=4, num_kv_heads=kv,
        head_dim=16, d_ff=0 if cfg.d_ff == 0 else 128, vocab_size=512,
        sliding_window=8, max_learned_pos=128, param_dtype="float32",
        accum_steps=1, opt_state_bf16=False,
    )
    if cfg.moe:
        kw["moe"] = MoEParams(num_experts=8, top_k=min(cfg.moe.top_k, 2),
                              d_ff_expert=64,
                              num_shared=min(cfg.moe.num_shared, 1))
    if cfg.mla:
        kw["mla"] = MLAParams(kv_lora_rank=32, q_lora_rank=48,
                              nope_head_dim=16, rope_head_dim=8,
                              v_head_dim=16)
    if cfg.ssd:
        kw["ssd"] = SSDParams(d_inner=128, state=16, nheads=8,
                              conv_width=4, chunk=16)
    if cfg.rglru:
        kw["rglru"] = RGLRUParams(width=64, conv_width=4)
    if cfg.encoder:
        kw["encoder"] = EncoderParams(num_layers=2, num_frames=16, d_ff=128)
    return replace(cfg, **kw)
