"""Cluster tier (DESIGN §14): partition directory, multi-node store,
incremental elastic rebalancing.

Built on the decomposition SNIPPETS §1 describes and Whiz
(arXiv:1703.10272) motivates — an explicit partition→location service
decoupled from compute:

* :mod:`.directory` — :class:`PartitionDirectory`: partition id → node
  (consistent-hash / range), versioned epochs, replication sets;
* :mod:`.node` — :class:`ClusterDurableStore`: the durable tier sharded
  across directories-as-nodes, replica-fallback reads;
* :mod:`.rebalancer` — :class:`Rebalancer`: minimal-move placement
  changes published through the store's atomic generation flip;
* :mod:`.control` — :class:`ClusterHealth`: heartbeats + straggler
  detection (the formerly-dormant runtime modules) feeding Autopilot
  signals.

Entry point: ``PartitionStore(root=..., cluster=ClusterConfig(...))`` or
``Session(store_path=..., cluster=ClusterConfig(nodes=("a", "b")))``.
"""

from .control import ClusterHealth, ClusterSignal
from .directory import (CONSISTENT_HASH, RANGE_PLACEMENT, STRATEGIES,
                        ClusterConfig, PartitionDirectory)
from .node import ClusterDurableStore, Node
from .rebalancer import (RebalanceAborted, RebalancePlan, RebalanceResult,
                         Rebalancer)

__all__ = ["ClusterConfig", "PartitionDirectory", "ClusterDurableStore",
           "Node", "Rebalancer", "RebalancePlan", "RebalanceResult",
           "RebalanceAborted", "ClusterHealth", "ClusterSignal",
           "CONSISTENT_HASH", "RANGE_PLACEMENT", "STRATEGIES"]
