"""Multi-node durable store — segment shards across directories-as-nodes.

:class:`ClusterDurableStore` extends the single-host durable tier
(DESIGN §10) so that one dataset generation's segment files are sharded
across a set of :class:`Node` roots according to the
:class:`~repro.cluster.directory.PartitionDirectory`::

    root/
      catalog.json                  # store identity (unchanged)
      cluster.json                  # node names, strategy, replication
      directory-000003.json         # immutable placement epochs
      EPOCH                         # pointer — current placement epoch
      datasets/<name>/
        CURRENT                     # unchanged commit protocol
        manifest-000007.json        # columns carry per-node "parts"
      nodes/<node>/datasets/<name>/
        gen-000007/<col>.seg        # this node's held partitions only

Each (node, column) *part* is one segment holding the concatenation of
the partitions that node holds (primary or replica), in ascending
partition order.  The manifest's column spec records every part — node,
partition list, primary sublist, relative path, byte count — so a
manifest is self-describing: a reader reassembles the full padded layout
from whatever holders are reachable WITHOUT consulting the directory,
which means an epoch flip can never strand a committed generation.

Nodes are plain directories, so the whole tier is testable on one host
and "killing a node" is removing its directory — exactly what the
two-process CI smoke (scripts/cluster_smoke.py) does.  Reads prefer a
partition's primary holder and fall back to replicas when the primary's
part is missing (killed node) or straggles (p50-window detection via
:class:`~repro.cluster.control.ClusterHealth` — the read is then
reissued against a replica, MapReduce-style speculative execution).

The commit protocol is unchanged from DESIGN §10 — parts → manifest →
CURRENT, each step atomic — with one addition: a *rebalance* publishes
each dataset under the new placement first and flips the EPOCH pointer
last, so a crash anywhere mid-rebalance reopens to individually
consistent datasets under the OLD committed epoch.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..obs.tracer import span as _span
from ..data.storage.durable import DurableStore, _encode_name
from ..data.storage.manifest import (Manifest, atomic_write_text,
                                     gen_dirname, load_manifest,
                                     manifest_filename, publish_manifest,
                                     segment_filename)
from ..data.storage.segments import (fsync_dir, open_segment, segment_valid,
                                     write_segment)
from .directory import ClusterConfig, PartitionDirectory

__all__ = ["Node", "ClusterDurableStore"]

_GEN_RE = re.compile(r"^gen-(\d{6})$")
_GENERATION_LOG_CAP = 64


def _cluster_zero() -> Dict[str, float]:
    return {"rebalance_bytes_moved_total": 0,
            "rebalance_replica_bytes_total": 0,
            "rebalance_bytes_linked_total": 0,
            "rebalance_partitions_moved_total": 0,
            "rebalances_total": 0,
            "epoch_bumps_total": 0,
            "parts_written_total": 0,
            "parts_read_total": 0}


@dataclass(frozen=True)
class Node:
    """One storage node: a named directory root holding its share of
    every dataset's segment parts."""
    name: str
    root: str

    def dataset_dir(self, dataset: str) -> str:
        return os.path.join(self.root, "datasets", _encode_name(dataset))

    def gen_dir(self, dataset: str, generation: int) -> str:
        return os.path.join(self.dataset_dir(dataset),
                            gen_dirname(generation))


class ClusterDurableStore(DurableStore):
    """Durable tier sharded across directories-as-nodes."""

    is_cluster = True

    def __init__(self, root: str, *, num_workers: Optional[int] = None,
                 max_retired_generations: int = 2,
                 cluster: Optional[ClusterConfig] = None):
        super().__init__(root, num_workers=num_workers,
                         max_retired_generations=max_retired_generations)
        self.cluster = self._load_or_init_cluster(cluster)
        m = self.num_workers
        if m is None:
            raise ValueError("a cluster store needs a known worker count "
                             "(num_workers) to place partitions")
        self.directory = PartitionDirectory.load_current(self.root)
        if self.directory is None:
            self.directory = PartitionDirectory.build(
                m, self.cluster.nodes, strategy=self.cluster.strategy,
                replication=self.cluster.replication)
            self.directory.publish(self.root)
        #: set by the owning PartitionStore — heartbeat/straggler tracking
        self.health = None
        self.cluster_stats: Dict[str, float] = _cluster_zero()
        self._cluster_lock = threading.Lock()
        for node in self.nodes.values():
            os.makedirs(node.root, exist_ok=True)

    # -- cluster identity ----------------------------------------------------
    @property
    def cluster_path(self) -> str:
        return os.path.join(self.root, "cluster.json")

    def _load_or_init_cluster(self, cluster: Optional[ClusterConfig]
                              ) -> ClusterConfig:
        import json
        try:
            with open(self.cluster_path) as f:
                # on-disk config is authoritative: membership changes go
                # through the Rebalancer (directory epochs), never the ctor
                return ClusterConfig.from_json(json.load(f))
        except OSError:
            pass
        if cluster is None:
            raise ValueError(
                f"{self.root} has no cluster.json — pass cluster="
                "ClusterConfig(nodes=...) to create a cluster store")
        atomic_write_text(self.cluster_path, json.dumps(cluster.to_json(),
                                                        indent=1))
        return cluster

    @property
    def nodes(self) -> Dict[str, Node]:
        """Live membership (the current directory epoch's nodes)."""
        return {n: Node(n, os.path.join(self.root, "nodes", n))
                for n in self.directory.nodes}

    def node_gen_dir(self, node: str, dataset: str, generation: int,
                     create: bool = False) -> str:
        d = os.path.join(self.root, "nodes", node, "datasets",
                         _encode_name(dataset), gen_dirname(generation))
        if create:
            os.makedirs(d, exist_ok=True)
        return d

    def cluster_add(self, **deltas: float) -> None:
        with self._cluster_lock:
            for k, v in deltas.items():
                self.cluster_stats[k] = self.cluster_stats.get(k, 0) + v

    def cluster_snapshot(self) -> Dict[str, float]:
        with self._cluster_lock:
            return dict(self.cluster_stats)

    def publish_directory(self, directory: PartitionDirectory) -> None:
        """Commit a new placement epoch (the rebalance commit point)."""
        directory.publish(self.root)
        self.directory = directory
        self.cluster_add(epoch_bumps_total=1)

    # -- write path (sharded parts) ------------------------------------------
    def persist(self, ds, publish_current: bool = True, *,
                directory: Optional[PartitionDirectory] = None,
                prev_man: Optional[Manifest] = None,
                acct: Optional[Dict[str, float]] = None) -> Manifest:
        """Durably publish one generation, sharding each column into
        per-node parts under ``directory`` (default: the current epoch).

        ``prev_man`` + ``acct`` is the incremental-rebalance path: parts
        whose (node, partition-list) is unchanged from ``prev_man`` are
        hard-linked instead of rewritten (zero cross-node traffic), and
        ``acct`` accumulates ``bytes_moved`` (partitions whose primary
        changed) / ``replica_bytes`` (new replica holders only)."""
        directory = directory or self.directory
        t0 = time.perf_counter()
        with _span("cluster.persist", "cluster", dataset=ds.name,
                   generation=ds.generation, epoch=directory.epoch) as sp:
            ds_dir = self.dataset_dir(ds.name, create=True)
            caps = np.asarray(ds.slot_capacities(), np.int64)
            offs = np.asarray(ds.slot_offsets(), np.int64)
            m = ds.num_workers
            holders: Dict[str, List[int]] = {n: [] for n in directory.nodes}
            for p in range(m):
                for nd in directory.replicas_of(p):
                    holders[nd].append(p)
            prev_parts, prev_holders = self._prev_placement(prev_man)
            columns: Dict[str, Dict[str, Any]] = {}
            written = 0
            touched_dirs = set()
            for col, v in sorted(ds.columns.items()):
                a = np.ascontiguousarray(np.asarray(v))
                flat = a.reshape((-1,) + a.shape[2:]) \
                    if ds.capacity_map is None else a
                rowbytes = int(a.dtype.itemsize
                               * int(np.prod(flat.shape[1:],
                                             dtype=np.int64)))
                spec: Dict[str, Any] = {
                    "dtype": a.dtype.str, "shape": list(a.shape),
                    "nbytes": int(a.nbytes), "parts": []}
                for node in directory.nodes:
                    ps = holders[node]
                    if not ps:
                        continue
                    ndir = self.node_gen_dir(node, ds.name, ds.generation,
                                             create=True)
                    path = os.path.join(ndir, segment_filename(col))
                    rel = os.path.relpath(path, ds_dir)
                    part_nbytes = int(sum(int(caps[p]) for p in ps)
                                      * rowbytes)
                    reused = False
                    prev = prev_parts.get((col, node))
                    if (prev is not None
                            and [int(p) for p in prev.get("partitions", ())]
                            == ps
                            and int(prev.get("nbytes", -1)) == part_nbytes):
                        src = os.path.join(ds_dir, prev["file"])
                        if segment_valid(src, part_nbytes):
                            reused = self._reuse_segment(src, path)
                    if reused:
                        self.cluster_add(
                            rebalance_bytes_linked_total=part_nbytes)
                    else:
                        chunk = np.concatenate(
                            [flat[offs[p]:offs[p] + int(caps[p])]
                             for p in ps]) if ps else flat[:0]
                        written += write_segment(path, chunk)
                        self.io_add(segments_written=1)
                        self.cluster_add(parts_written_total=1)
                    touched_dirs.add(ndir)
                    spec["parts"].append({
                        "node": node, "partitions": list(ps),
                        "primary": [p for p in ps
                                    if directory.replica_sets[p][0] == node],
                        "file": rel, "nbytes": part_nbytes})
                if acct is not None and prev_holders:
                    self._account_moves(acct, holders, prev_holders,
                                        directory, caps, rowbytes)
                columns[col] = spec
            for d in sorted(touched_dirs):
                fsync_dir(d)
            if prev_man is None and ds.generation > 0:
                prev_man = load_manifest(ds_dir, ds.generation - 1)
            man = Manifest.of_dataset(ds, prev_man)
            man.generation_log = man.generation_log[-_GENERATION_LOG_CAP:]
            man.columns = columns
            if publish_current:
                publish_manifest(ds_dir, man)
                self._gc(ds_dir, ds.generation)
            else:
                atomic_write_text(
                    os.path.join(ds_dir, manifest_filename(man.generation)),
                    man.to_json())
            self.io_add(bytes_written=written,
                        write_s=time.perf_counter() - t0,
                        generations_published=1)
            sp.set(bytes=written, nodes=len(directory.nodes))
            return man

    @staticmethod
    def _prev_placement(prev_man: Optional[Manifest]
                        ) -> Tuple[Dict, Dict[int, set]]:
        """(col, node) → part spec, and partition → holder-node set, of
        the previous generation's placement (empty when fresh)."""
        prev_parts: Dict = {}
        prev_holders: Dict[int, set] = {}
        if prev_man is None:
            return prev_parts, prev_holders
        for col, spec in prev_man.columns.items():
            for part in spec.get("parts", ()):
                prev_parts[(col, part["node"])] = part
                for p in part["partitions"]:
                    prev_holders.setdefault(int(p), set()).add(part["node"])
        return prev_parts, prev_holders

    @staticmethod
    def _account_moves(acct, holders, prev_holders, directory, caps,
                       rowbytes) -> None:
        """Cross-node traffic this column: bytes of every (node, partition)
        pair that is a NEW holder.  Primary-ownership changes count as
        ``bytes_moved`` (the incremental-rebalance acceptance metric);
        new replica holders count separately as ``replica_bytes``."""
        for node, ps in holders.items():
            for p in ps:
                if node in prev_holders.get(p, ()):
                    continue
                b = int(caps[p]) * rowbytes
                if directory.replica_sets[p][0] == node:
                    acct["bytes_moved"] = acct.get("bytes_moved", 0) + b
                else:
                    acct["replica_bytes"] = acct.get("replica_bytes", 0) + b

    @staticmethod
    def _reuse_segment(src: str, dst: str) -> bool:
        """Reuse an unchanged part for the new generation: hard link
        (same node, zero bytes), falling back to a local copy."""
        try:
            if os.path.exists(dst):
                os.remove(dst)
            os.link(src, dst)
            return True
        except OSError:
            try:
                shutil.copyfile(src, dst)
                return True
            except OSError:
                return False

    def _gc(self, ds_dir: str, current_gen: int) -> None:
        super()._gc(ds_dir, current_gen)
        enc = os.path.basename(ds_dir)
        keep_from = current_gen - self.max_retired_generations
        nodes_root = os.path.join(self.root, "nodes")
        try:
            node_names = os.listdir(nodes_root)
        except OSError:
            return
        for node in node_names:
            nd = os.path.join(nodes_root, node, "datasets", enc)
            try:
                names = os.listdir(nd)
            except OSError:
                continue
            for n in names:
                mt = _GEN_RE.match(n)
                if mt and int(mt.group(1)) < keep_from:
                    shutil.rmtree(os.path.join(nd, n), ignore_errors=True)

    # -- read path (reassembly with replica fallback) ------------------------
    def open_columns(self, name: str, man: Manifest) -> Dict[str, np.ndarray]:
        ds_dir = self.dataset_dir(name)
        out: Dict[str, np.ndarray] = {}
        t0 = time.perf_counter()
        total = 0
        for col, spec in sorted(man.columns.items()):
            if "parts" not in spec:
                # pre-cluster generation (store grown into a cluster):
                # plain single-segment column
                out[col] = open_segment(os.path.join(ds_dir, spec["file"]),
                                        spec["dtype"],
                                        tuple(spec["shape"]))
                continue
            arr, nread = self._assemble_column(ds_dir, man, col, spec)
            out[col] = arr
            total += nread
        if total:
            self.io_add(bytes_read=total,
                        read_s=time.perf_counter() - t0)
        return out

    def _assemble_column(self, ds_dir: str, man: Manifest, col: str,
                         spec: Dict[str, Any]) -> Tuple[np.ndarray, int]:
        """Reassemble one column's padded layout from its node parts.

        Two passes: (1) each partition from its PRIMARY holder, deferring
        reads the straggler detector flags; (2) any remaining partition
        from ANY holder whose part is readable — the replica-fallback /
        speculative-reissue path.  Raises when some partition has no
        readable holder at all (data loss beyond the replication factor).
        """
        shape = tuple(int(s) for s in spec["shape"])
        dtype = np.dtype(spec["dtype"])
        arr = np.zeros(shape, dtype)
        if arr.size == 0:
            return arr, 0
        m = int(man.num_workers)
        if man.capacity_map is not None:
            caps = np.asarray(man.capacity_map, np.int64)
            flat = arr
        else:
            caps = np.full(m, int(man.capacity), np.int64)
            flat = arr.reshape((m * int(man.capacity),) + shape[2:])
        offs = np.concatenate([[0], np.cumsum(caps)[:-1]])
        row_shape = flat.shape[1:]
        filled = caps == 0        # zero-capacity partitions hold no rows
        nread = 0
        for primary_pass in (True, False):
            if filled.all():
                break
            for part in spec["parts"]:
                want = part["primary"] if primary_pass else part["partitions"]
                need = [p for p in want if not filled[p]]
                if not need:
                    continue
                data = self._read_part(ds_dir, part, dtype, row_shape,
                                       defer_stragglers=primary_pass)
                if data is None:
                    continue
                nread += int(data.nbytes)
                off = 0
                local: Dict[int, int] = {}
                for p in part["partitions"]:
                    local[int(p)] = off
                    off += int(caps[p])
                for p in need:
                    lo = local[int(p)]
                    flat[offs[p]:offs[p] + caps[p]] = data[lo:lo + caps[p]]
                    filled[p] = True
        missing = np.flatnonzero(~filled)
        if missing.size:
            raise OSError(
                f"dataset {man.name!r} column {col!r}: partitions "
                f"{missing.tolist()} unreadable from every holding node "
                f"(replication={self.cluster.replication})")
        return arr, nread

    def _read_part(self, ds_dir: str, part: Dict[str, Any], dtype, row_shape,
                   defer_stragglers: bool) -> Optional[np.ndarray]:
        """Read one node part eagerly, feeding its latency to the
        straggler detector.  Returns None when the part is missing /
        truncated (killed node) or — on the primary pass — when the read
        straggled, so the caller reissues against a replica holder."""
        path = os.path.join(ds_dir, part["file"])
        if not segment_valid(path, part["nbytes"]):
            return None
        t0 = time.perf_counter()
        try:
            data = np.fromfile(path, dtype=dtype)
        except OSError:
            return None
        self.cluster_add(parts_read_total=1)
        h = self.health
        if h is not None:
            lat = h.observed_latency(part["node"],
                                     time.perf_counter() - t0)
            if h.record_read(part["node"], lat) and defer_stragglers:
                return None
        rowlen = int(np.prod(row_shape, dtype=np.int64)) if row_shape else 1
        if rowlen <= 0 or data.size % rowlen:
            return None            # torn part: replica pass will retry
        return data.reshape((-1,) + tuple(row_shape))
