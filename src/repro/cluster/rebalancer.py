"""Incremental Rebalancer — minimal-move placement changes (DESIGN §14).

When the node set changes (add, remove, loss) the Rebalancer:

1. **plans** — builds the next directory epoch and diffs it against the
   current one: the move set is exactly the partitions whose PRIMARY
   entry changed (consistent hashing keeps that near ``m/n`` for a
   single-node change), plus the elastic mesh replan
   (:mod:`repro.runtime.elastic`) the new device count implies;
2. **applies** — for every dataset, republishes the current generation's
   rows under the new placement as a NEW generation through the store's
   existing atomic pointer flip (``_install``): unchanged (node,
   partition-set) parts are hard-linked (zero traffic), only changed
   parts stream to their new nodes.  Concurrent MVCC readers holding the
   previous generation keep a consistent view throughout, and the
   generation bump invalidates exactly the cached plans that compiled
   against the old placement (PR 4 semantics);
3. **commits** — flips the EPOCH pointer LAST.  A crash mid-apply leaves
   some datasets republished and some not — every one individually
   consistent — under the OLD epoch; reads stay bit-identical because a
   manifest is self-describing (parts carry their own node paths).

Apply never contacts dead nodes: dataset rows come from the resident
in-memory generation (assembled from surviving replicas at attach), so
draining a lost node is the same code path as planned scale-in.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.tracer import span as _span
from ..runtime.elastic import MeshPlan, replan_mesh
from .directory import PartitionDirectory

__all__ = ["RebalancePlan", "RebalanceResult", "Rebalancer",
           "RebalanceAborted"]


class RebalanceAborted(RuntimeError):
    """Raised by the test-only ``abort_after`` hook to simulate a crash
    mid-rebalance (after N datasets republished, before the epoch flip)."""


@dataclass
class RebalancePlan:
    """One priced, appliable placement change."""
    old_epoch: int
    directory: PartitionDirectory          # the proposed next epoch
    moved: Tuple[Tuple[int, str, str], ...]  # (partition, old, new) primaries
    replica_changes: int
    datasets: Tuple[str, ...]
    est_bytes_moved: int                   # primary-move bytes, exact
    reason: str = ""
    mesh: Optional[MeshPlan] = None        # elastic replan for the new set
    mesh_error: str = ""                   # e.g. fewer devices than model axis

    @property
    def partitions_moved(self) -> int:
        return len(self.moved)

    def explain(self) -> str:
        frac = self.partitions_moved / max(self.directory.m, 1)
        lines = [
            f"rebalance epoch {self.old_epoch} -> {self.directory.epoch} "
            f"({self.reason or 'membership change'})",
            f"  nodes: {', '.join(self.directory.nodes)}",
            f"  moves: {self.partitions_moved}/{self.directory.m} "
            f"partitions ({frac:.0%}), ~{self.est_bytes_moved} bytes "
            f"primary + {self.replica_changes} replica holder changes",
        ]
        if self.mesh is not None:
            lines.append(f"  mesh: {self.mesh.shape} over {self.mesh.axes}")
        if self.mesh_error:
            lines.append(f"  mesh: UNPLANNABLE ({self.mesh_error})")
        return "\n".join(lines)


@dataclass
class RebalanceResult:
    epoch: int
    partitions_moved: int
    bytes_moved: int
    replica_bytes: int
    bytes_linked: int
    wall_s: float
    generations: Dict[str, int] = field(default_factory=dict)


class Rebalancer:
    """Plans and applies incremental placement changes for one cluster
    :class:`~repro.data.partition_store.PartitionStore`."""

    def __init__(self, store):
        if not getattr(store, "is_cluster", False):
            raise ValueError("rebalancer needs a cluster store "
                             "(PartitionStore(cluster=ClusterConfig(...)))")
        self.store = store

    # -- planning ------------------------------------------------------------
    def plan(self, *, add_nodes: Sequence[str] = (),
             remove_nodes: Sequence[str] = (),
             nodes: Optional[Sequence[str]] = None,
             reason: str = "") -> RebalancePlan:
        """Plan the move set for a membership change (either an explicit
        target ``nodes`` list, or the current set ± add/remove)."""
        cur = self.store.directory
        if nodes is None:
            removed = {str(n) for n in remove_nodes}
            new_nodes = [n for n in cur.nodes if n not in removed]
            new_nodes += [str(n) for n in add_nodes
                          if str(n) not in new_nodes]
        else:
            new_nodes = [str(n) for n in nodes]
        if not new_nodes:
            raise ValueError("cannot rebalance to an empty node set")
        if tuple(new_nodes) == cur.nodes:
            raise ValueError("node set unchanged — nothing to rebalance")
        new_dir = cur.with_nodes(new_nodes)
        moved = tuple(cur.diff(new_dir))
        names = tuple(sorted(self.store.datasets))
        est = self._estimate_moved_bytes(names, [p for p, _, _ in moved])
        cfg = self.store.cluster_config
        mesh, mesh_error = None, ""
        try:
            current_mesh = MeshPlan(
                (max(1, len(cur.nodes) * cfg.devices_per_node
                     // cfg.model_axis), cfg.model_axis),
                ("data", "model"))
            mesh = replan_mesh(current_mesh,
                               len(new_nodes) * cfg.devices_per_node)
        except ValueError as e:
            mesh_error = str(e)
        return RebalancePlan(
            old_epoch=cur.epoch, directory=new_dir, moved=moved,
            replica_changes=cur.replica_changes(new_dir),
            datasets=names, est_bytes_moved=est, reason=reason,
            mesh=mesh, mesh_error=mesh_error)

    def _estimate_moved_bytes(self, names: Sequence[str],
                              moved_partitions: Sequence[int]) -> int:
        """Exact padded bytes of the moved partitions' slots across every
        dataset (what the primary moves will stream)."""
        total = 0
        for name in names:
            try:
                ds = self.store.read(name)
            except KeyError:
                continue
            caps = np.asarray(ds.slot_capacities(), np.int64)
            slots = int(ds.total_slots)
            if slots <= 0:
                continue
            per_slot = ds.padded_bytes / slots
            total += int(sum(int(caps[p]) for p in moved_partitions)
                         * per_slot)
        return total

    # -- application ---------------------------------------------------------
    def apply(self, plan: RebalancePlan,
              abort_after: Optional[int] = None,
              on_abort=None) -> RebalanceResult:
        """Execute ``plan``: republish every dataset under the new
        placement (atomic per-dataset pointer flips), then commit the
        epoch.  ``abort_after=N`` (tests/smoke only) raises after N
        datasets, simulating a crash before the epoch commit;
        ``on_abort`` (a callable) runs at the crash point *while the
        ``cluster.rebalance`` span is still open* — the smoke uses it to
        spill the trace buffer exactly as a dying process would, so the
        in-flight span reaches the merged trace as ``incomplete``."""
        store, durable = self.store, self.store.durable
        if plan.old_epoch != store.directory.epoch:
            raise ValueError(
                f"plan is stale: built against epoch {plan.old_epoch}, "
                f"store is at {store.directory.epoch}")
        t0 = time.perf_counter()
        acct: Dict[str, float] = {}
        generations: Dict[str, int] = {}
        with _span("cluster.rebalance", "cluster",
                   epoch=plan.directory.epoch, reason=plan.reason,
                   partitions_moved=plan.partitions_moved,
                   datasets=len(plan.datasets)) as sp:
            done = 0
            for name in plan.datasets:
                try:
                    ds = store.read(name)
                except KeyError:
                    continue
                prev_man = durable.load_manifest(name, ds.generation)
                new = self._restamped(ds)
                store._install(
                    name, new,
                    persist=lambda d, pm=prev_man: durable.persist(
                        d, directory=plan.directory, prev_man=pm,
                        acct=acct))
                generations[name] = new.generation
                done += 1
                if abort_after is not None and done >= abort_after:
                    if on_abort is not None:
                        on_abort()
                    raise RebalanceAborted(
                        f"simulated crash after {done} dataset(s), "
                        "before epoch commit")
            # the commit point: everything above is invisible to a fresh
            # process until this pointer flips
            durable.publish_directory(plan.directory)
            health = getattr(store, "health", None)
            if health is not None:
                health.reset_nodes(plan.directory.nodes)
            durable.cluster_add(
                rebalances_total=1,
                rebalance_bytes_moved_total=acct.get("bytes_moved", 0),
                rebalance_replica_bytes_total=acct.get("replica_bytes", 0),
                rebalance_partitions_moved_total=plan.partitions_moved)
            wall = time.perf_counter() - t0
            sp.set(bytes_moved=int(acct.get("bytes_moved", 0)),
                   wall_s=wall)
        return RebalanceResult(
            epoch=plan.directory.epoch,
            partitions_moved=plan.partitions_moved,
            bytes_moved=int(acct.get("bytes_moved", 0)),
            replica_bytes=int(acct.get("replica_bytes", 0)),
            bytes_linked=int(durable.cluster_snapshot()
                             .get("rebalance_bytes_linked_total", 0)),
            wall_s=wall, generations=generations)

    @staticmethod
    def _restamped(ds):
        """The same rows/columns as ``ds``, as a fresh StoredDataset the
        store can install as the next generation (columns are shared —
        a rebalance changes placement, not data)."""
        from ..data.partition_store import StoredDataset
        return StoredDataset(
            name=ds.name, columns=dict(ds.columns), counts=ds.counts,
            partitioner=ds.partitioner, num_rows=ds.num_rows,
            nbytes=ds.nbytes, generation=ds.generation,
            capacity_map=ds.capacity_map)
