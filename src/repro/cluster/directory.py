"""PartitionDirectory — the partition→node placement service (DESIGN §14).

The directory is the cluster tier's single source of truth for *where*
each of a store's ``m`` logical partitions lives.  It is deliberately a
small, versioned, serializable value object — the shape Whiz
(arXiv:1703.10272) argues for: decoupling the data-organization service
(an explicit partition→location map) from compute is what makes
placement-aware optimization possible at cluster scale.

Two placement strategies:

* ``consistent-hash`` — nodes project virtual points onto a stable hash
  ring (sha1, never Python's randomized ``hash``); a partition is owned
  by the first node clockwise of its own ring point.  Adding or removing
  one node therefore moves only ~``m/n`` partitions — the property the
  incremental :class:`~repro.cluster.rebalancer.Rebalancer` exploits.
* ``range`` — contiguous partition ranges per node (locality-friendly;
  more movement on membership change).

Every membership or shape change produces a NEW directory with
``epoch + 1`` — directories are immutable values, and the epoch is the
placement generation the planner pins into its PhysicalPlan cache key
(a rebalance bumps the epoch, which invalidates exactly the plans that
compiled against the old placement).

Replication-set metadata: each partition carries an ordered replica set
(primary first, ``replication`` distinct nodes total when the cluster is
large enough).  The multi-node store persists a partition's segments to
every holder, so the loss of any single node leaves every partition
readable from a survivor.

Durability follows the manifest idiom (DESIGN §10): immutable
``directory-<epoch>.json`` files plus an ``EPOCH`` pointer rewritten by
temp-then-atomic-rename; loading prefers the pointer and falls back to
the newest parseable epoch, so a crash mid-rebalance reopens to the last
committed placement.
"""

from __future__ import annotations

import bisect
import hashlib
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..data.storage.manifest import atomic_write_text

__all__ = ["ClusterConfig", "PartitionDirectory", "CONSISTENT_HASH",
           "RANGE_PLACEMENT", "STRATEGIES", "EPOCH_POINTER"]

CONSISTENT_HASH = "consistent-hash"
RANGE_PLACEMENT = "range"
STRATEGIES = (CONSISTENT_HASH, RANGE_PLACEMENT)

EPOCH_POINTER = "EPOCH"
_DIRECTORY_RE = re.compile(r"^directory-(\d{6})\.json$")

#: virtual ring points per node — enough to keep the per-node partition
#: share within a few percent of uniform at the m values the repo uses
VIRTUAL_POINTS = 64


def _stable_hash(s: str) -> int:
    """64-bit hash stable across processes and Python versions (the ring
    must be identical for every process that opens the cluster)."""
    return int.from_bytes(hashlib.sha1(s.encode()).digest()[:8], "big")


def _directory_filename(epoch: int) -> str:
    return f"directory-{epoch:06d}.json"


@dataclass
class ClusterConfig:
    """Static cluster identity: node names, placement strategy,
    replication factor.  Persisted once as ``cluster.json`` next to the
    store catalog; the on-disk copy is authoritative on reopen (node-set
    changes go through the Rebalancer, never through the constructor)."""

    nodes: Tuple[str, ...]
    strategy: str = CONSISTENT_HASH
    replication: int = 2
    #: accelerator devices each node contributes — what the elastic mesh
    #: replan (runtime/elastic.py) converts a membership change into
    devices_per_node: int = 1
    #: model-parallel axis size the mesh replan must preserve
    model_axis: int = 1

    def __post_init__(self):
        self.nodes = tuple(str(n) for n in self.nodes)
        if not self.nodes:
            raise ValueError("cluster needs at least one node")
        if len(set(self.nodes)) != len(self.nodes):
            raise ValueError(f"duplicate node names: {self.nodes}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown placement strategy "
                             f"{self.strategy!r}; one of {STRATEGIES}")
        if int(self.replication) < 1:
            raise ValueError("replication factor must be >= 1")

    def to_json(self) -> Dict:
        return {"nodes": list(self.nodes), "strategy": self.strategy,
                "replication": int(self.replication),
                "devices_per_node": int(self.devices_per_node),
                "model_axis": int(self.model_axis)}

    @classmethod
    def from_json(cls, d: Dict) -> "ClusterConfig":
        return cls(nodes=tuple(d["nodes"]),
                   strategy=d.get("strategy", CONSISTENT_HASH),
                   replication=int(d.get("replication", 2)),
                   devices_per_node=int(d.get("devices_per_node", 1)),
                   model_axis=int(d.get("model_axis", 1)))


@dataclass
class PartitionDirectory:
    """One immutable placement epoch: partition id → ordered replica set
    (primary first).  ``lookups`` is the only mutable field — a plain
    observability counter (GIL-atomic ``+=``), excluded from equality."""

    m: int
    nodes: Tuple[str, ...]
    strategy: str
    replication: int
    epoch: int
    replica_sets: Tuple[Tuple[str, ...], ...]
    lookups: int = field(default=0, compare=False)

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, m: int, nodes: Sequence[str], *,
              strategy: str = CONSISTENT_HASH, replication: int = 2,
              epoch: int = 0) -> "PartitionDirectory":
        nodes = tuple(str(n) for n in nodes)
        if not nodes:
            raise ValueError("cannot place partitions on zero nodes")
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown placement strategy {strategy!r}")
        r = min(int(replication), len(nodes))
        if strategy == CONSISTENT_HASH:
            sets = cls._consistent_hash_sets(int(m), nodes, r)
        else:
            sets = cls._range_sets(int(m), nodes, r)
        return cls(m=int(m), nodes=nodes, strategy=strategy,
                   replication=int(replication), epoch=int(epoch),
                   replica_sets=sets)

    @staticmethod
    def _consistent_hash_sets(m: int, nodes: Tuple[str, ...],
                              r: int) -> Tuple[Tuple[str, ...], ...]:
        ring: List[Tuple[int, str]] = sorted(
            (_stable_hash(f"{node}#{v}"), node)
            for node in nodes for v in range(VIRTUAL_POINTS))
        points = [h for h, _ in ring]
        sets: List[Tuple[str, ...]] = []
        for p in range(m):
            i = bisect.bisect_right(points, _stable_hash(f"partition-{p}"))
            chosen: List[str] = []
            for k in range(len(ring)):
                node = ring[(i + k) % len(ring)][1]
                if node not in chosen:
                    chosen.append(node)
                    if len(chosen) == r:
                        break
            sets.append(tuple(chosen))
        return tuple(sets)

    @staticmethod
    def _range_sets(m: int, nodes: Tuple[str, ...],
                    r: int) -> Tuple[Tuple[str, ...], ...]:
        n = len(nodes)
        sets: List[Tuple[str, ...]] = []
        for p in range(m):
            owner = min(p * n // max(m, 1), n - 1)
            sets.append(tuple(nodes[(owner + k) % n] for k in range(r)))
        return tuple(sets)

    # -- lookups (the router path) -------------------------------------------
    def node_of(self, partition: int) -> str:
        """Primary owner of ``partition`` (counts as a directory lookup)."""
        self.lookups += 1
        return self.replica_sets[partition][0]

    def replicas_of(self, partition: int) -> Tuple[str, ...]:
        """Ordered replica set of ``partition``, primary first."""
        self.lookups += 1
        return self.replica_sets[partition]

    def partitions_of(self, node: str) -> List[int]:
        """Partitions ``node`` owns as primary."""
        return [p for p in range(self.m) if self.replica_sets[p][0] == node]

    def holders_of(self, node: str) -> List[int]:
        """Partitions ``node`` holds at all (primary or replica)."""
        return [p for p in range(self.m) if node in self.replica_sets[p]]

    # -- membership / shape changes (each returns a NEW epoch) ----------------
    def with_nodes(self, nodes: Sequence[str]) -> "PartitionDirectory":
        return PartitionDirectory.build(
            self.m, nodes, strategy=self.strategy,
            replication=self.replication, epoch=self.epoch + 1)

    def with_m(self, m: int) -> "PartitionDirectory":
        return PartitionDirectory.build(
            m, self.nodes, strategy=self.strategy,
            replication=self.replication, epoch=self.epoch + 1)

    def diff(self, new: "PartitionDirectory"
             ) -> List[Tuple[int, str, str]]:
        """Partitions whose PRIMARY owner differs under ``new`` —
        ``[(partition, old_node, new_node)]``.  The incremental move set:
        everything else stays put."""
        if new.m != self.m:
            raise ValueError(f"diff across partition counts "
                            f"({self.m} vs {new.m}) is a re-shuffle, "
                            "not a rebalance")
        return [(p, self.replica_sets[p][0], new.replica_sets[p][0])
                for p in range(self.m)
                if self.replica_sets[p][0] != new.replica_sets[p][0]]

    def replica_changes(self, new: "PartitionDirectory") -> int:
        """(partition, node) holder pairs that are new under ``new`` but
        whose primary did NOT change — pure replica churn."""
        changes = 0
        for p in range(self.m):
            if self.replica_sets[p][0] != new.replica_sets[p][0]:
                continue
            changes += len(set(new.replica_sets[p])
                           - set(self.replica_sets[p]))
        return changes

    # -- serialization --------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "epoch": int(self.epoch), "m": int(self.m),
            "strategy": self.strategy, "replication": int(self.replication),
            "nodes": list(self.nodes),
            # explicit sets, not re-derived: a reopened process must see the
            # exact placement this epoch committed, even across algorithm
            # tweaks in future builds
            "replica_sets": [list(rs) for rs in self.replica_sets],
        }, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "PartitionDirectory":
        d = json.loads(text)
        return cls(m=int(d["m"]), nodes=tuple(d["nodes"]),
                   strategy=d["strategy"],
                   replication=int(d["replication"]),
                   epoch=int(d["epoch"]),
                   replica_sets=tuple(tuple(rs)
                                      for rs in d["replica_sets"]))

    # -- durable publication (manifest idiom, DESIGN §10) ---------------------
    def publish(self, root: str) -> None:
        """Commit this epoch: immutable ``directory-<epoch>.json``, then
        flip the ``EPOCH`` pointer (the rebalance commit point)."""
        atomic_write_text(os.path.join(root, _directory_filename(self.epoch)),
                          self.to_json())
        atomic_write_text(os.path.join(root, EPOCH_POINTER),
                          str(int(self.epoch)))

    @classmethod
    def load_current(cls, root: str) -> Optional["PartitionDirectory"]:
        """Newest epoch that parses, preferring the one EPOCH points at —
        a crash between the epoch file and the pointer (or mid-rebalance,
        before either) degrades to the last committed placement."""
        candidates: List[int] = []
        try:
            with open(os.path.join(root, EPOCH_POINTER)) as f:
                candidates.append(int(f.read().strip()))
        except (OSError, ValueError):
            pass
        epochs = []
        try:
            for n in os.listdir(root):
                mt = _DIRECTORY_RE.match(n)
                if mt:
                    epochs.append(int(mt.group(1)))
        except OSError:
            return None
        for e in sorted(epochs, reverse=True):
            if e not in candidates:
                candidates.append(e)
        for e in candidates:
            try:
                with open(os.path.join(root, _directory_filename(e))) as f:
                    return cls.from_json(f.read())
            except (OSError, ValueError, KeyError):
                continue
        return None
