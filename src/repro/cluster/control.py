"""Cluster control plane — health signals the Autopilot can price.

This module is the wiring layer that turns the three previously-dormant
runtime modules into the cluster tier's failure/straggler detector:

* :mod:`repro.runtime.fault_tolerance` — ``Coordinator`` heartbeats:
  a node that misses ``miss_threshold`` consecutive control-plane ticks
  is declared lost.
* :mod:`repro.runtime.straggler` — ``StragglerMitigator``'s p50-window
  detector, fed by per-part segment read latencies from the multi-node
  store: a node whose reads repeatedly exceed ``factor × p50`` is a
  straggler (reads are transparently reissued against a replica holder;
  persistent slowness escalates to a signal).
* :mod:`repro.runtime.elastic` — consumed by the Rebalancer, which
  converts a membership change into a mesh replan.

Detection does NOT act.  It emits :class:`ClusterSignal` values that the
Autopilot drains on its next tick (`signals()`), prices with the what-if
cost model, and answers with a rebalance decision — the same
observe→price→decide→apply loop every other layout decision takes, so a
lost node shows up in ``decisions.log`` with a full why-record.

Determinism: the clock is a logical step counter the caller advances
(``tick(step)``), latencies can be injected per node
(``set_read_latency``), so every failure mode is reproducible on one
host with no sleeps.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.fault_tolerance import Coordinator, FailureEvent
from ..runtime.straggler import StragglerConfig, StragglerMitigator

__all__ = ["ClusterSignal", "ClusterHealth"]

#: a node must straggle this many reads (within the mitigator window)
#: before detection escalates from per-read reissue to a cluster signal
STRAGGLER_SIGNAL_DETECTIONS = 3


@dataclass
class ClusterSignal:
    """One health event awaiting an Autopilot decision."""
    kind: str                     # "node_lost" | "straggler"
    node: str
    step: int
    detail: Dict[str, float] = field(default_factory=dict)


class ClusterHealth:
    """Heartbeat + straggler tracking over a named node set.

    Thread-safety: the store's read path calls :meth:`record_read` from
    serving threads while the Autopilot thread drives :meth:`tick` /
    :meth:`signals`; one lock serializes all state transitions (none of
    them are hot — reads take the lock once per *segment part*, not per
    row)."""

    def __init__(self, nodes: Sequence[str], *, miss_threshold: int = 3,
                 straggler: Optional[StragglerConfig] = None,
                 straggler_signal_detections: int =
                 STRAGGLER_SIGNAL_DETECTIONS):
        self.miss_threshold = int(miss_threshold)
        self.straggler_cfg = straggler or StragglerConfig()
        self.straggler_signal_detections = int(straggler_signal_detections)
        self._lock = threading.Lock()
        #: cumulative missed-beat count across every node and epoch
        self.heartbeat_misses = 0
        #: test hook — fn(node) -> Optional[seconds] overriding measured
        #: read latency (deterministic straggler reproduction, no sleeps)
        self._latency_injector: Optional[Callable[[str],
                                                  Optional[float]]] = None
        self._pending: List[ClusterSignal] = []
        self._signalled: set = set()          # (kind, node) dedupe
        self.reset_nodes(nodes)

    # -- membership ----------------------------------------------------------
    def reset_nodes(self, nodes: Sequence[str]) -> None:
        """Adopt a new node set (called after a rebalance commits a new
        placement epoch).  Health state restarts: the new epoch's nodes
        all begin alive with fresh straggler windows."""
        with self._lock:
            self._nodes = tuple(str(n) for n in nodes)
            self._index = {n: i for i, n in enumerate(self._nodes)}
            self.coordinator = Coordinator(
                len(self._nodes), miss_threshold=self.miss_threshold)
            self.mitigator = StragglerMitigator(self.straggler_cfg)
            self._node_lat: Dict[str, Deque[float]] = {
                n: deque(maxlen=self.straggler_cfg.window)
                for n in self._nodes}
            self._node_detections: Dict[str, int] = dict.fromkeys(
                self._nodes, 0)
            self._step = 0
            self._signalled = {s for s in self._signalled
                               if s[1] in self._index}

    @property
    def nodes(self) -> Tuple[str, ...]:
        return self._nodes

    def node_index(self, node: str) -> int:
        return self._index[node]

    def node_name(self, index: int) -> str:
        return self._nodes[index]

    # -- heartbeats (fault_tolerance wiring) ---------------------------------
    def heartbeat(self, node: str, step: Optional[int] = None) -> None:
        """A node posts liveness for ``step`` (default: the current one)."""
        with self._lock:
            if node not in self._index:
                return
            self.coordinator.heartbeat(
                self._index[node], self._step if step is None else int(step))

    def tick(self, step: Optional[int] = None,
             checkpoint_step: int = 0) -> List[ClusterSignal]:
        """Advance failure detection one logical step.  Call ONCE per
        control-plane step — the Coordinator counts a missed beat per
        call for every stale node.  Returns the signals newly raised by
        this tick (they also queue for :meth:`signals`)."""
        new: List[ClusterSignal] = []
        with self._lock:
            self._step = self._step + 1 if step is None else int(step)
            before = {w: h.missed
                      for w, h in self.coordinator.workers.items()}
            ev: Optional[FailureEvent] = self.coordinator.tick(
                self._step, checkpoint_step)
            for w, h in self.coordinator.workers.items():
                if h.missed > before.get(w, 0):
                    self.heartbeat_misses += h.missed - before[w]
            # a worker that just crossed the threshold keeps its count
            # (alive=False freezes it); failures past the first within one
            # tick surface on subsequent ticks, one per call
            if ev is not None:
                node = self._nodes[ev.worker]
                sig = self._raise("node_lost", node, {
                    "missed": float(
                        self.coordinator.workers[ev.worker].missed),
                    "restart_step": float(ev.restart_step)})
                if sig is not None:
                    new.append(sig)
        return new

    def alive_nodes(self) -> List[str]:
        with self._lock:
            return [self._nodes[w] for w in self.coordinator.alive_workers()]

    def dead_nodes(self) -> List[str]:
        with self._lock:
            alive = set(self.coordinator.alive_workers())
            return [n for i, n in enumerate(self._nodes) if i not in alive]

    # -- read-path straggler detection (straggler wiring) --------------------
    def set_read_latency(self, fn: Optional[Callable[[str],
                                                     Optional[float]]]
                         ) -> None:
        """Install (or with ``None`` remove) a per-node latency injector
        for tests; injected values replace measured wall time."""
        self._latency_injector = fn

    def observed_latency(self, node: str, measured: float) -> float:
        fn = self._latency_injector
        if fn is not None:
            injected = fn(node)
            if injected is not None:
                return float(injected)
        return measured

    def record_read(self, node: str, latency: float) -> bool:
        """Feed one per-part segment read into the p50-window detector.
        Returns True when this read straggled (latency > factor × p50) —
        the store's cue to reissue against a replica holder.  A node
        accumulating ``straggler_signal_detections`` straggled reads
        raises a ``straggler`` signal for the Autopilot."""
        with self._lock:
            thr = self.mitigator.threshold()
            straggled = thr is not None and latency > thr
            if straggled:
                idx = self._index.get(node, -1)
                self.mitigator.detections.append((self._step, idx, latency))
                self.mitigator.reissues += 1
                self._node_detections[node] = \
                    self._node_detections.get(node, 0) + 1
                if (self._node_detections[node]
                        >= self.straggler_signal_detections):
                    self._raise("straggler", node, {
                        "latency_s": float(latency),
                        "threshold_s": float(thr),
                        "excess_s": float(latency - thr /
                                          self.straggler_cfg.factor),
                        "detections": float(self._node_detections[node])})
            self.mitigator.record(latency)
            lat = self._node_lat.get(node)
            if lat is not None:
                lat.append(latency)
            return straggled

    @property
    def straggler_reissues(self) -> int:
        return self.mitigator.reissues

    def straggler_excess_s(self, node: str) -> float:
        """How much slower than the cluster median this node's recent
        reads run (seconds per read; 0 when unknown)."""
        with self._lock:
            lat = self._node_lat.get(node)
            if not lat or len(self.mitigator.samples) == 0:
                return 0.0
            p50 = float(np.percentile(self.mitigator.samples, 50))
            return max(0.0, float(np.mean(lat)) - p50)

    # -- signal queue (Autopilot inlet) --------------------------------------
    def _raise(self, kind: str, node: str,
               detail: Dict[str, float]) -> Optional[ClusterSignal]:
        """Queue a signal once per (kind, node) until membership changes
        (reset_nodes clears handled entries) — lock held by caller."""
        key = (kind, node)
        if key in self._signalled:
            return None
        self._signalled.add(key)
        sig = ClusterSignal(kind=kind, node=node, step=self._step,
                            detail=dict(detail))
        self._pending.append(sig)
        return sig

    def signals(self) -> List[ClusterSignal]:
        """Drain pending signals (each delivered exactly once)."""
        with self._lock:
            out, self._pending = self._pending, []
            return out
