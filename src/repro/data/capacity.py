"""Variable per-partition capacity layout (skew-adaptive storage).

The padded ``(m, capacity)`` layout sizes every partition for the fullest
one, so a single hot key inflates padding bytes for all ``m`` partitions.
A :class:`CapacityMap` gives each partition its own power-of-two capacity
bucket: hot partitions keep a large bucket while cold partitions share
small ones.  Columns of a bucketed dataset are stored *flat* as
``(total_slots,) + trailing`` with partition ``i`` occupying the slot
range ``[offsets[i], offsets[i] + capacities[i])``.

Power-of-two bucketing keeps the set of distinct capacities small, so the
jitted shuffle plans (keyed on the padded output row count, see
``device_repartition.shape_bucket``) stay bounded across skew levels: the
capacities ride through the trace as a regular traced array, never as a
static shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "CapacityMap",
    "bucket_capacity",
    "plan_capacity_map",
    "valid_slot_index",
]


def bucket_capacity(count: int) -> int:
    """Round ``count`` up to its power-of-two capacity bucket (0 stays 0)."""
    c = int(count)
    if c <= 0:
        return 0
    return 1 << (c - 1).bit_length()


@dataclass(frozen=True)
class CapacityMap:
    """Per-partition slot capacities + exclusive-prefix-sum offsets.

    ``capacities[i]`` is the number of slots reserved for partition ``i``;
    ``offsets[i]`` is where partition ``i`` starts in the flat slot axis.
    Instances are immutable and shared across dataset generations.
    """

    capacities: np.ndarray  # (m,) int64
    offsets: np.ndarray  # (m,) int64, exclusive prefix sum
    total_slots: int

    @classmethod
    def of(cls, capacities: Sequence[int]) -> "CapacityMap":
        caps = np.asarray(capacities, dtype=np.int64)
        offs = np.zeros_like(caps)
        if caps.size:
            np.cumsum(caps[:-1], out=offs[1:])
        cm = cls(capacities=caps, offsets=offs, total_slots=int(caps.sum()))
        caps.setflags(write=False)
        offs.setflags(write=False)
        return cm

    @classmethod
    def uniform(cls, m: int, capacity: int) -> "CapacityMap":
        return cls.of(np.full(int(m), int(capacity), dtype=np.int64))

    @classmethod
    def from_counts(cls, counts: Sequence[int]) -> "CapacityMap":
        """Bucket each partition's row count to its own power-of-two."""
        caps = np.asarray(
            [bucket_capacity(c) for c in np.asarray(counts, dtype=np.int64)],
            dtype=np.int64,
        )
        return cls.of(caps)

    @property
    def num_partitions(self) -> int:
        return int(self.capacities.shape[0])

    def bucket_set(self) -> Tuple[int, ...]:
        """Sorted distinct non-zero capacities (small by construction)."""
        return tuple(sorted({int(c) for c in self.capacities if c > 0}))

    def is_uniform(self) -> bool:
        if not self.capacities.size:
            return True
        return bool((self.capacities == self.capacities[0]).all())

    def __eq__(self, other: object) -> bool:  # frozen dataclass w/ arrays
        if not isinstance(other, CapacityMap):
            return NotImplemented
        return self.total_slots == other.total_slots and np.array_equal(
            self.capacities, other.capacities
        )

    def __hash__(self) -> int:
        return hash((self.total_slots, self.capacities.tobytes()))


def plan_capacity_map(
    counts: Sequence[int], threshold: float = 0.75
) -> Optional[CapacityMap]:
    """Propose a bucketed layout for ``counts``, or None to stay uniform.

    Returns a :class:`CapacityMap` only when the bucketed total slot count
    is at most ``threshold`` of the uniform layout's ``m * max(counts)``
    (i.e. the re-layout saves at least ``1 - threshold`` of the padding).
    """
    cnts = np.asarray(counts, dtype=np.int64)
    if cnts.size == 0 or int(cnts.sum()) == 0:
        return None
    uniform_total = int(cnts.shape[0]) * bucket_capacity(int(cnts.max()))
    cm = CapacityMap.from_counts(cnts)
    if uniform_total <= 0 or cm.total_slots > threshold * uniform_total:
        return None
    return cm


def valid_slot_index(counts: Sequence[int], offsets: Sequence[int]) -> np.ndarray:
    """Flat slot indices of the valid rows, worker-major in rank order.

    This is the single source of truth for gather/flatten ordering: row
    ``r`` of partition ``i`` lives at slot ``offsets[i] + r``, and valid
    rows are enumerated partition-by-partition.  Both the uniform layout
    (``offsets = arange(m) * capacity``) and bucketed layouts share it,
    which is what makes the two layouts bit-identical on read.
    """
    cnts = np.asarray(counts, dtype=np.int64)
    offs = np.asarray(offsets, dtype=np.int64)
    n = int(cnts.sum())
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.repeat(offs, cnts)
    # rank within partition: arange(n) minus each partition's first global row
    row_starts = np.repeat(np.cumsum(cnts) - cnts, cnts)
    return starts + (np.arange(n, dtype=np.int64) - row_starts)
