"""Device-resident repartition path (DESIGN §5).

The paper's dispatch hot spot — hash the partition key, histogram the
destinations, re-bucket every column — runs here as a **single-pass device
shuffle**: one jitted pipeline per shape bucket that hashes, counting-sorts
and permutes/scatters without ever leaving the device.  Three consumers:

* the :class:`~repro.data.partition_store.PartitionStore` device write path
  (:func:`device_scatter_padded` — scatter flat rows into the persistent
  ``(m, capacity, ...)`` layout),
* the engine's repartition node (:func:`device_rebucket` /
  :func:`device_rebucket_full` — re-bucket a flat intermediate into worker
  segments), and
* :func:`device_repartition_dataset` — the device-to-device fast path that
  reshuffles a device-resident ``StoredDataset`` into a new layout without
  a host ``gather()``.

**Dispatch plans.**  A :class:`ShufflePlan` is the jitted
hash → counting-sort → permute/scatter pipeline for one
``(shape-bucket, dtype-set, m, capacity)`` key.  Row counts are padded up to
a power-of-two bucket and the valid count rides along as a traced scalar
(scalar-prefetched into the kernel), so repeated shuffles of any N in the
bucket reuse one trace — ``plan_cache_stats()`` exposes the trace counter
the no-retrace tests assert on.  Same-dtype round-trippable columns are
packed into a single ``(B, C)`` matrix, so K columns cost one gather/scatter
and one host sync, not K.

**Counting sort, not argsort.**  Each row's destination is its stable
counting-sort position: per-partition base offsets from an exclusive prefix
sum over the histogram plus a running stable rank — an O(N) placement
replacing the O(N log N) ``jnp.argsort`` + per-column eager gather the old
path paid.  Two executions of the same math, picked per backend
(``mode``):

* ``"fused"`` (TPU default) — everything inside one jit: the
  ``hash_partition_padded`` kernel emits pids with padding routed to an
  overflow partition ``m``, ``scatter_perm`` computes the permutation with
  an in-kernel prefix sum, and the packed gather/scatter rides the same
  trace.  One device dispatch per shuffle.
* ``"hostperm"`` (CPU default) — XLA-on-CPU sorts/scatters are an order of
  magnitude slower than numpy, so the permutation is computed host-side
  (numpy radix sort over small-int pids: O(N)) and only the packed
  gather — the part XLA-CPU is actually good at — stays jitted.  Plans are
  still cached and traced exactly once per bucket.

Bit-identical guarantee: both modes apply the same Wang hash as
``core.ir._mix_hash`` and reproduce the stable-sort order exactly — no
arithmetic touches the payload — so device results match the host numpy
path bit-for-bit (asserted by the kernel, plan, and property tests).  With
jax's default x64-disabled config, 64-bit payload columns cannot round-trip
through jnp; those are gathered host-side by the same permutation (hybrid
gather), preserving exact bits and dtypes either way.
"""

from __future__ import annotations

import threading

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hash_partition.ops import (padded_partition_ids,
                                          partition_ids, scatter_permutation)
from ..obs.tracer import span as _span
from .capacity import CapacityMap, bucket_capacity, valid_slot_index

Columns = Dict[str, Any]

MODES = ("fused", "hostperm")


def default_interpret() -> bool:
    """Pallas kernels need interpret mode anywhere but a real TPU."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def default_use_kernel() -> bool:
    """Kernels compile on TPU; elsewhere the jitted jnp oracle is the
    bit-identical stand-in (interpret-mode kernels are correctness coverage,
    exercised explicitly by the kernel tests)."""
    return jax.default_backend() == "tpu"


def _resolve_use_kernel(use_kernel: Optional[bool]) -> bool:
    return default_use_kernel() if use_kernel is None else use_kernel


def default_mode() -> str:
    return "fused" if jax.default_backend() == "tpu" else "hostperm"


def _resolve_mode(mode: Optional[str]) -> str:
    mode = default_mode() if mode is None else mode
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    return mode


def dtype_roundtrips(dtype) -> bool:
    """True if jnp.asarray preserves this dtype under the active jax config
    (x64-disabled canonicalizes int64/float64 down — those columns must stay
    host-side to keep the bit-identical guarantee)."""
    return jnp.asarray(np.empty(0, dtype)).dtype == np.dtype(dtype)


def as_kernel_keys(keys) -> jax.Array:
    """Normalize a key column for the hash kernel.

    Mirrors ``core.ir._mix_hash``'s dtype handling exactly (float32 bits are
    reinterpreted, everything else is cast to int32 with jnp's canonical
    truncation) so kernel pids equal host pids bit-for-bit.  Device-resident
    keys are normalized with jnp ops — no host round-trip.
    """
    if isinstance(keys, jax.Array):
        k = keys.reshape(-1)
        if jnp.issubdtype(k.dtype, jnp.integer):
            return k.astype(jnp.int32)
        if k.dtype == jnp.float32:
            return k.view(jnp.int32)
        return k.astype(jnp.int32)
    k = np.asarray(keys).reshape(-1)
    if np.issubdtype(k.dtype, np.integer):
        return jnp.asarray(k.astype(np.int32))
    if k.dtype == np.float64:                     # jnp canonicalizes f64→f32
        k = k.astype(np.float32)
    if k.dtype == np.float32:
        return jnp.asarray(k.view(np.int32))
    return jnp.asarray(k.astype(np.int32))


def _host_kernel_keys(keys) -> np.ndarray:
    """Host-side twin of :func:`as_kernel_keys` (int32, same truncation)."""
    k = np.asarray(keys).reshape(-1)
    if np.issubdtype(k.dtype, np.integer) or k.dtype == np.bool_:
        return k.astype(np.int32)
    if k.dtype == np.float64:
        k = k.astype(np.float32)
    if k.dtype == np.float32:
        return k.view(np.int32)
    return k.astype(np.int32)


def _host_wang(x: np.ndarray) -> np.ndarray:
    """Numpy twin of ref.wang_hash — identical uint32 arithmetic."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint32)
        x = (x ^ np.uint32(61)) ^ (x >> np.uint32(16))
        x = x * np.uint32(9)
        x = x ^ (x >> np.uint32(4))
        x = x * np.uint32(0x27D4EB2D)
        x = x ^ (x >> np.uint32(15))
    return x


@partial(jax.jit, static_argnames=("num_partitions",))
def _hash_pids_jit(keys, num_partitions: int) -> jax.Array:
    """Elementwise hash → pid, no histogram (the histogram is cheaper on
    the host when the permutation is computed there anyway)."""
    from ..kernels.hash_partition.ref import wang_hash
    return (wang_hash(keys) % jnp.uint32(num_partitions)).astype(jnp.int32)


def device_partition_ids(keys, num_partitions: int, *,
                         interpret: Optional[bool] = None,
                         use_kernel: Optional[bool] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Kernel dispatch: keys → (pids (N,) int32, histogram (m,) int32)."""
    keys = as_kernel_keys(keys)
    if keys.shape[0] == 0:           # zero-size grids crash pallas_call
        return (jnp.zeros(0, jnp.int32),
                jnp.zeros(num_partitions, jnp.int32))
    return partition_ids(keys, num_partitions,
                         interpret=_resolve_interpret(interpret),
                         use_kernel=_resolve_use_kernel(use_kernel))


def shuffle_pids(keys, num_partitions: int, *,
                 interpret: Optional[bool] = None,
                 use_kernel: Optional[bool] = None,
                 mode: Optional[str] = None
                 ) -> Tuple[Any, np.ndarray]:
    """Mode-matched pid computation: ``(pids, counts (m,) np.int64)``.

    fused → kernel/oracle hash+histogram on device (pids stay device);
    hostperm → device keys hash through a tiny jitted elementwise pass, host
    keys hash with the numpy Wang twin; histogram via np.bincount.
    """
    mode = _resolve_mode(mode)
    if mode == "fused":
        pids, hist = device_partition_ids(keys, num_partitions,
                                          interpret=interpret,
                                          use_kernel=use_kernel)
        return pids, np.asarray(hist).astype(np.int64)
    if isinstance(keys, jax.Array):
        # bucket the key length so the elementwise jit never retraces per N
        k = as_kernel_keys(keys)
        n = int(k.shape[0])
        B = shape_bucket(n)
        k_p = k if n == B else jnp.zeros(B, jnp.int32).at[:n].set(k)
        pids = np.asarray(_hash_pids_jit(k_p, num_partitions))[:n]
    else:
        pids = (_host_wang(_host_kernel_keys(keys))
                % np.uint32(num_partitions)).astype(np.int32)
    counts = np.bincount(pids, minlength=num_partitions).astype(np.int64)
    return pids, counts


# ---------------------------------------------------------------------------
# Host counting-sort placement (shared with the store's host dispatch)
# ---------------------------------------------------------------------------

def host_counting_order(pids: np.ndarray) -> np.ndarray:
    """Stable order of rows grouped by pid — numpy radix sort (O(N)) when
    the pids fit in int16, stable mergesort otherwise.  Identical output to
    ``np.argsort(pids, kind="stable")`` either way."""
    if pids.size and pids.max(initial=0) < np.iinfo(np.int16).max:
        return np.argsort(pids.astype(np.int16), kind="stable")
    return np.argsort(pids, kind="stable")


def host_counting_sort_dest(pids: np.ndarray, counts: np.ndarray,
                            cap: int,
                            dest_offsets: Optional[np.ndarray] = None
                            ) -> np.ndarray:
    """Flat destination slot (partition base + stable rank-within-pid) of
    every row — one vectorized counting-sort placement shared by all
    columns.  The uniform layout's base is ``pid * cap``; a bucketed layout
    passes its own per-partition ``dest_offsets``."""
    n = pids.shape[0]
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    order = host_counting_order(pids)
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n, dtype=np.int64) - offsets[pids[order]]
    if dest_offsets is None:
        return pids * cap + rank
    return np.asarray(dest_offsets, dtype=np.int64)[pids] + rank


# ---------------------------------------------------------------------------
# Shape buckets and column packing
# ---------------------------------------------------------------------------

def shape_bucket(n: int) -> int:
    """Pad row counts up to a power of two so nearby Ns share one trace."""
    return max(8, 1 << (int(n) - 1).bit_length())


@dataclass
class _Pack:
    """Same-dtype round-trippable columns flattened into one (rows, C)
    matrix — one upload + one gather/scatter + one download per dtype."""
    dtype: np.dtype
    width: int                                   # C = sum of member widths
    members: List[Tuple[str, Tuple[int, ...], int, int]]  # name, trail, c0, c1
    data: Any = None                             # (rows, C) np or jax array


def _split_columns(columns: Columns,
                   device_columns: Optional[Columns] = None
                   ) -> Tuple[List[Tuple[str, Any]], List[Tuple[str, Any]]]:
    """(device-eligible cols, host-only cols); device-resident copies from
    ``device_columns`` are preferred so an upstream device stage's output
    feeds the next shuffle without re-uploading."""
    dev, host = [], []
    for k, v in columns.items():
        src = v
        if device_columns is not None and k in device_columns:
            src = device_columns[k]
        dt = src.dtype if isinstance(src, jax.Array) else np.asarray(v).dtype
        if dtype_roundtrips(dt):
            dev.append((k, src))
        else:
            host.append((k, np.asarray(v)))
    return dev, host


def _build_packs(dev_cols: List[Tuple[str, Any]], n: int,
                 rows: int) -> List[_Pack]:
    """Group device-eligible columns by dtype into (rows, C) pack matrices;
    rows beyond n are zero padding (never read back)."""
    groups: Dict[str, _Pack] = {}
    for name, v in dev_cols:
        dt = np.dtype(str(v.dtype))
        trail = tuple(v.shape[1:])
        w = int(np.prod(trail)) if trail else 1
        p = groups.setdefault(str(dt), _Pack(dtype=dt, width=0, members=[]))
        p.members.append((name, trail, p.width, p.width + w))
        p.width += w
    packs = sorted(groups.values(), key=lambda p: str(p.dtype))
    by_name = dict(dev_cols)
    for p in packs:
        on_device = any(isinstance(by_name[nm], jax.Array)
                        for nm, *_ in p.members)
        if on_device:         # keep the pack on device — no host round-trip
            flat = [jnp.asarray(by_name[nm]).reshape(n, -1)
                    for nm, *_ in p.members]
            cat = flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)
            p.data = jnp.zeros((rows, p.width), p.dtype).at[:n].set(cat)
        else:
            buf = np.zeros((rows, p.width), p.dtype)
            for nm, _trail, c0, c1 in p.members:
                buf[:n, c0:c1] = np.asarray(by_name[nm]).reshape(n, -1)
            p.data = buf                     # one jnp upload at call time
    return packs


def _pack_spec(packs: List[_Pack]) -> Tuple[Tuple[str, int], ...]:
    return tuple((str(p.dtype), p.width) for p in packs)


# ---------------------------------------------------------------------------
# ShufflePlan: the jitted permute/scatter pipelines, cached per shape bucket
# ---------------------------------------------------------------------------

@dataclass
class ShufflePlan:
    """One compiled dispatch plan, keyed on
    (kind, shape-bucket, dtype-set, m, capacity, mode)."""
    key: Tuple
    fn: Callable = None
    traces: int = 0          # bumped inside the traced body — retrace counter
    calls: int = 0


# LRU-bounded plan cache.  A long-lived optimizer service shuffles many
# (shape-bucket, dtype-set, m, capacity) keys over its lifetime; an unbounded
# dict would pin every jitted executable it ever traced.  Least-recently-used
# plans are evicted past the capacity; their trace/call counters fold into
# ``_RETIRED`` so ``plan_cache_stats()`` totals stay monotone across
# evictions (the no-retrace assertions keep working).
_PLANS: "OrderedDict[Tuple, ShufflePlan]" = OrderedDict()
_PLAN_CACHE_CAPACITY = 64
_RETIRED = {"plans": 0, "traces": 0, "calls": 0}
# Guards _PLANS/_RETIRED: the serving tier dispatches shuffles from many
# threads (DESIGN §11); an unguarded OrderedDict corrupts under concurrent
# get/move_to_end/popitem.  Cheap — plan *lookup* is a dict hit; the jit
# trace itself happens lazily at first call, outside this lock.
_PLANS_LOCK = threading.RLock()


def plan_cache_stats() -> Dict[str, int]:
    """(plans, traces, calls, evictions) across the process — ``plans`` is
    the live-plan count; ``traces``/``calls`` include evicted plans so a flat
    ``traces`` across repeated same-shape shuffles stays the no-retrace
    guarantee even after LRU turnover."""
    with _PLANS_LOCK:
        return {"plans": len(_PLANS),
                "traces": sum(p.traces for p in _PLANS.values())
                + _RETIRED["traces"],
                "calls": sum(p.calls for p in _PLANS.values())
                + _RETIRED["calls"],
                "evictions": _RETIRED["plans"]}


def reset_plan_cache_stats() -> None:
    """Zero the trace/call counters without dropping any compiled plan —
    the companion to :func:`plan_cache_stats` for a long-lived service that
    wants per-window "did anything retrace?" checks."""
    with _PLANS_LOCK:
        for p in _PLANS.values():
            p.traces = 0
            p.calls = 0
        _RETIRED.update(plans=0, traces=0, calls=0)


def clear_plan_cache() -> None:
    """Drop every plan and all counters (tests start from a clean slate)."""
    with _PLANS_LOCK:
        _PLANS.clear()
        _RETIRED.update(plans=0, traces=0, calls=0)


def set_plan_cache_capacity(capacity: int) -> None:
    """Bound the live-plan count; evicts LRU plans immediately if needed."""
    global _PLAN_CACHE_CAPACITY
    if capacity < 1:
        raise ValueError("plan cache capacity must be >= 1")
    with _PLANS_LOCK:
        _PLAN_CACHE_CAPACITY = capacity
        _evict_to_capacity()


def plan_cache_capacity() -> int:
    return _PLAN_CACHE_CAPACITY


def _evict_to_capacity() -> None:
    # caller holds _PLANS_LOCK
    while len(_PLANS) > _PLAN_CACHE_CAPACITY:
        _key, plan = _PLANS.popitem(last=False)
        _RETIRED["plans"] += 1
        _RETIRED["traces"] += plan.traces
        _RETIRED["calls"] += plan.calls


def _get_plan(key: Tuple, build: Callable[[ShufflePlan], Callable]
              ) -> ShufflePlan:
    with _PLANS_LOCK:
        plan = _PLANS.get(key)
        if plan is None:
            # building the wrapper is cheap (jax.jit is lazy); the actual
            # trace happens at first call, outside the lock — concurrent
            # first calls of one plan serialize inside jax, trace once
            plan = ShufflePlan(key=key)
            plan.fn = jax.jit(build(plan))
            _PLANS[key] = plan
            _evict_to_capacity()
        else:
            _PLANS.move_to_end(key)
        return plan


def _fused_rebucket_plan(m: int, B: int, spec: Tuple, interpret: bool,
                         use_kernel: bool) -> ShufflePlan:
    """keys + dynamic n + packs → (order, counts, gathered packs), one jit:
    hash kernel (padding → overflow partition m) → counting-sort kernel →
    permutation inversion → packed gather."""
    key = ("rebucket", m, B, spec, interpret, use_kernel, "fused")

    def build(plan: ShufflePlan):
        def fn(keys, n, packs):
            plan.traces += 1
            pids, counts_full = padded_partition_ids(
                keys, n, m, interpret=interpret, use_kernel=use_kernel)
            dest = scatter_permutation(pids, counts_full,
                                       interpret=interpret,
                                       use_kernel=use_kernel)
            # invert the counting-sort placement → gather permutation
            order = jnp.zeros(B, jnp.int32).at[dest].set(
                jnp.arange(B, dtype=jnp.int32))
            outs = tuple(jnp.take(p, order, axis=0) for p in packs)
            return order, counts_full[:m], outs
        return fn

    return _get_plan(key, build)


def _hostperm_rebucket_plan(m: int, B: int, spec: Tuple) -> ShufflePlan:
    """host-computed counting-sort order + packs → gathered packs (the one
    stage XLA-on-CPU is fast at stays jitted and retrace-free)."""
    key = ("rebucket", m, B, spec, "hostperm")

    def build(plan: ShufflePlan):
        def fn(order, packs):
            plan.traces += 1
            return tuple(jnp.take(p, order, axis=0) for p in packs)
        return fn

    return _get_plan(key, build)


def _fused_scatter_plan(m: int, B: int, R: int, spec: Tuple,
                        interpret: bool, use_kernel: bool) -> ShufflePlan:
    """pids + counts + dynamic (n, slot offsets) + packs → flat (R, C) packs.

    The per-partition destination base offsets ride along as a traced
    ``(m,)`` array and the output rows are bucketed to ``R ≥ total slots``
    (+1 trash slot), so same-shape writes with different key skew — and
    uniform vs bucketed :class:`CapacityMap` layouts alike — reuse one
    trace; the caller slices ``[:total]`` eagerly outside the jit.  The
    uniform layout simply passes ``offsets = arange(m) * cap``."""
    key = ("scatter", m, B, R, spec, interpret, use_kernel, "fused")

    def build(plan: ShufflePlan):
        def fn(pids, counts, n, slot_offs, packs):
            plan.traces += 1
            counts_full = jnp.concatenate(
                [counts.astype(jnp.int32),
                 (jnp.int32(B) - n.astype(jnp.int32)).reshape(1)])
            dest = scatter_permutation(pids, counts_full,
                                       interpret=interpret,
                                       use_kernel=use_kernel)
            offs = jnp.cumsum(counts_full) - counts_full
            rank = dest - offs[pids]
            # real rows → partition base + rank; padding rows (pid == m) →
            # the trash slot R (the clamped take is discarded by the where)
            base = jnp.take(slot_offs, jnp.minimum(pids, m - 1))
            flat_dest = jnp.where(pids < m, base + rank, R)
            outs = tuple(
                jnp.zeros((R + 1, p.shape[1]), p.dtype)
                .at[flat_dest].set(p)[:R]
                for p in packs)
            return flat_dest, outs
        return fn

    return _get_plan(key, build)


def _hostperm_scatter_plan(m: int, B: int, R: int,
                           spec: Tuple) -> ShufflePlan:
    """Gather-formulated padded scatter: ``inv`` maps every (worker, slot)
    to its source row (B = the all-zeros trash row for empty slots), so the
    layout materializes as one packed gather — XLA-CPU scatters are slow,
    its gathers are not.  Output rows are bucketed to ``R ≥ m * cap`` so
    different capacities share one trace."""
    key = ("scatter", m, B, R, spec, "hostperm")

    def build(plan: ShufflePlan):
        def fn(inv, packs):
            plan.traces += 1
            return tuple(jnp.take(p, inv, axis=0) for p in packs)
        return fn

    return _get_plan(key, build)


# ---------------------------------------------------------------------------
# Re-bucket (engine repartition node)
# ---------------------------------------------------------------------------

@dataclass
class ShuffleResult:
    """Output of a device shuffle: host-materialized columns for the
    engine's columnar compute plus the device-resident flats so a chained
    device stage (store write, next shuffle) skips the re-upload."""
    columns: Columns                     # np columns incl "__key__"
    counts: np.ndarray                   # (m,) int64
    device_columns: Optional[Columns] = None    # flat jax arrays (subset)


def device_rebucket_full(columns: Columns, key_vals, num_partitions: int, *,
                         interpret: Optional[bool] = None,
                         use_kernel: Optional[bool] = None,
                         mode: Optional[str] = None,
                         device_columns: Optional[Columns] = None
                         ) -> ShuffleResult:
    """Re-bucket flat columns by hash(key) % m through one cached plan.

    Single-pass shuffle (hash → histogram → counting-sort permutation →
    packed gather); K same-dtype columns cost one gather and one host sync.
    ``device_columns`` (flat jax arrays from an upstream device stage) are
    consumed in place of re-uploading the matching host columns.
    """
    interpret = _resolve_interpret(interpret)
    use_kernel = _resolve_use_kernel(use_kernel)
    mode = _resolve_mode(mode)
    key_arr = key_vals if isinstance(key_vals, jax.Array) \
        else np.asarray(key_vals).reshape(-1)
    n = int(key_arr.shape[0])
    m = int(num_partitions)
    if n == 0:
        out = {k: np.asarray(v).copy() for k, v in columns.items()}
        out["__key__"] = np.asarray(key_arr)
        return ShuffleResult(out, np.zeros(m, np.int64), None)

    cols = dict(columns)
    cols["__key__"] = key_arr
    if device_columns:
        # a relayed "__key__" is the *previous* shuffle's key — never let it
        # shadow the key this node is partitioning on
        device_columns = {k: v for k, v in device_columns.items()
                          if k != "__key__"}
        if isinstance(key_arr, jax.Array):
            device_columns["__key__"] = key_arr
    dev_cols, host_cols = _split_columns(cols, device_columns)
    B = shape_bucket(n)
    packs = _build_packs(dev_cols, n, B)
    spec = _pack_spec(packs)

    with _span("shuffle.dispatch", "shuffle", op="rebucket", rows=n, m=m,
               bucket=B, mode=mode):
        if mode == "fused":
            keys_p = jnp.zeros(B, jnp.int32).at[:n].set(
                as_kernel_keys(key_arr))
            plan = _fused_rebucket_plan(m, B, spec, interpret, use_kernel)
            plan.calls += 1
            order_d, counts_d, outs_d = plan.fn(
                keys_p, jnp.int32(n),
                tuple(jnp.asarray(p.data) for p in packs))
            # one transfer for everything the host needs
            order_np, counts_np, outs_np = jax.device_get(
                (order_d, counts_d, outs_d))
            order_valid = order_np[:n]
            counts_np = counts_np.astype(np.int64)
        else:
            pids_np, counts_np = shuffle_pids(key_arr, m, mode="hostperm")
            order_valid = host_counting_order(pids_np)
            order_p = np.concatenate(
                [order_valid, np.arange(n, B)]).astype(np.int32)
            plan = _hostperm_rebucket_plan(m, B, spec)
            plan.calls += 1
            outs_d = plan.fn(jnp.asarray(order_p),
                             tuple(jnp.asarray(p.data) for p in packs))
            outs_np = jax.device_get(outs_d)

    out: Columns = {}
    device_out: Columns = {}
    for p, mat_d, mat_np in zip(packs, outs_d, outs_np):
        for name, trail, c0, c1 in p.members:
            out[name] = np.ascontiguousarray(
                mat_np[:n, c0:c1]).reshape((n,) + trail)
            device_out[name] = mat_d[:n, c0:c1].reshape((n,) + trail)
    for name, v in host_cols:
        out[name] = v[order_valid]
    return ShuffleResult(out, counts_np, device_out or None)


def device_rebucket(columns: Columns, key_vals, num_partitions: int, *,
                    interpret: Optional[bool] = None,
                    use_kernel: Optional[bool] = None,
                    mode: Optional[str] = None
                    ) -> Tuple[Columns, np.ndarray]:
    """Compatibility wrapper: ``(new_columns incl "__key__", counts)`` —
    the same contract as the engine's host-side shuffle."""
    res = device_rebucket_full(columns, key_vals, num_partitions,
                               interpret=interpret, use_kernel=use_kernel,
                               mode=mode)
    return res.columns, res.counts


# ---------------------------------------------------------------------------
# Padded scatter (store write path)
# ---------------------------------------------------------------------------

def _check_overflow(counts_np: np.ndarray, capacities: np.ndarray) -> None:
    """Raise a diagnosable error when any partition outgrows its capacity
    (the scatter would silently clamp/drop the overflowing rows)."""
    over = np.flatnonzero(counts_np > capacities)
    if over.size:
        pid = int(over[int(np.argmax((counts_np - capacities)[over]))])
        need = int(counts_np[pid])
        have = int(capacities[pid])
        raise ValueError(
            f"partition {pid} has {need} rows but capacity {have}: the "
            f"scatter would silently drop/clamp overflowing rows "
            f"(suggest overflow bucket capacity {bucket_capacity(need)} "
            f"for partition {pid}, e.g. via CapacityMap.from_counts)")


def device_scatter_padded(flat_columns: Columns, pids, counts, *,
                          capacity: Optional[int] = None,
                          capacity_map: Optional[CapacityMap] = None,
                          interpret: Optional[bool] = None,
                          use_kernel: Optional[bool] = None,
                          mode: Optional[str] = None,
                          device_columns: Optional[Columns] = None
                          ) -> Columns:
    """Scatter flat rows into the persistent padded layout.

    Uniform layout (default): ``(m, capacity, ...)`` columns.  With a
    ``capacity_map``, each partition gets its own slot range and columns
    come back *flat* as ``(total_slots, ...)`` — partition ``i`` occupies
    ``[offsets[i], offsets[i] + capacities[i])``.  Both shapes ride the
    same cached plan: the per-partition base offsets are a traced array, so
    switching skew levels (or uniform ↔ bucketed within one output-row
    bucket) never retraces.

    One cached counting-sort plan per (bucket, dtype-set, m, row-bucket):
    destination slot of row i is ``base[pids[i]] + rank-of-i-within-its-
    partition``, materialized per dtype *pack* — K same-dtype columns cost
    one scatter.  Round-trippable columns come back device-resident (jax
    arrays); 64-bit columns are scattered host-side (hybrid).

    A ``capacity`` (or capacity-map bucket) smaller than its partition's
    row count would silently clamp/drop rows inside the scatter, so it
    raises instead, naming the offending partition.
    """
    interpret = _resolve_interpret(interpret)
    use_kernel = _resolve_use_kernel(use_kernel)
    mode = _resolve_mode(mode)
    counts_np = np.asarray(counts).astype(np.int64)
    m = int(counts_np.shape[0])
    n = int(counts_np.sum())
    max_count = int(counts_np.max()) if n else 0
    if capacity_map is not None:
        if capacity is not None:
            raise ValueError("pass capacity or capacity_map, not both")
        if capacity_map.num_partitions != m:
            raise ValueError(
                f"capacity_map covers {capacity_map.num_partitions} "
                f"partitions, counts cover {m}")
        _check_overflow(counts_np, capacity_map.capacities)
        offsets_np = capacity_map.offsets.astype(np.int64)
        total = capacity_map.total_slots
        cap = 0
    else:
        if capacity is not None and int(capacity) < max_count:
            _check_overflow(counts_np,
                            np.full(m, int(capacity), dtype=np.int64))
        cap = int(capacity) if capacity is not None else max_count
        offsets_np = np.arange(m, dtype=np.int64) * cap
        total = m * cap

    def _shape(trail: Tuple[int, ...]) -> Tuple[int, ...]:
        if capacity_map is not None:
            return (total,) + trail
        return (m, cap) + trail

    if n == 0:
        if capacity_map is None:
            cap = cap or 1
        out: Columns = {}
        for k, v in flat_columns.items():
            v = np.asarray(v)
            if dtype_roundtrips(v.dtype):      # stay device-backed
                out[k] = jnp.zeros(_shape(v.shape[1:]), v.dtype)
            else:
                out[k] = np.zeros(_shape(v.shape[1:]), v.dtype)
        return out

    dev_cols, host_cols = _split_columns(flat_columns, device_columns)
    B = shape_bucket(n)
    R = shape_bucket(total)  # output-row bucket: offsets traced, not keyed

    with _span("shuffle.dispatch", "shuffle", op="scatter", rows=n, m=m,
               bucket=B, mode=mode):
        if mode == "fused":
            packs = _build_packs(dev_cols, n, B)
            if isinstance(pids, jax.Array):
                pids_p = jnp.full(B, m, jnp.int32).at[:n].set(
                    pids.astype(jnp.int32))
            else:
                buf = np.full(B, m, np.int32)
                buf[:n] = np.asarray(pids).astype(np.int32)
                pids_p = jnp.asarray(buf)
            plan = _fused_scatter_plan(m, B, R, _pack_spec(packs), interpret,
                                       use_kernel)
            plan.calls += 1
            flat_dest_d, outs = plan.fn(
                pids_p, jnp.asarray(counts_np.astype(np.int32)),
                jnp.int32(n), jnp.asarray(offsets_np.astype(np.int32)),
                tuple(jnp.asarray(p.data) for p in packs))
            flat_dest_np = None
            if host_cols:
                flat_dest_np = np.asarray(flat_dest_d)[:n]
        else:
            # rows [n:B] of each pack are zeros; row B is the explicit trash
            # source every empty (worker, slot) cell gathers from
            packs = _build_packs(dev_cols, n, B + 1)
            pids_np = np.asarray(pids).astype(np.int64)
            flat_dest_np = host_counting_sort_dest(pids_np, counts_np, cap,
                                                   dest_offsets=offsets_np)
            inv = np.full(R, B, np.int32)
            inv[flat_dest_np] = np.arange(n, dtype=np.int32)
            plan = _hostperm_scatter_plan(m, B, R, _pack_spec(packs))
            plan.calls += 1
            outs = plan.fn(jnp.asarray(inv),
                           tuple(jnp.asarray(p.data) for p in packs))

    columns: Columns = {}
    for p, mat in zip(packs, outs):
        # eager slice from the row bucket down to the real layout
        if capacity_map is not None:
            flat = mat[:total]
            for name, trail, c0, c1 in p.members:
                columns[name] = flat[:, c0:c1].reshape((total,) + trail)
        else:
            grid = mat[:total].reshape(m, cap, p.width)
            for name, trail, c0, c1 in p.members:
                columns[name] = grid[:, :, c0:c1].reshape((m, cap) + trail)
    for name, v in host_cols:
        buf = np.zeros((total + 1,) + v.shape[1:], v.dtype)
        buf[flat_dest_np] = v
        columns[name] = buf[:total].reshape(_shape(v.shape[1:]))
    return columns


# ---------------------------------------------------------------------------
# Device-to-device dataset repartition (store fast path)
# ---------------------------------------------------------------------------

def _valid_slot_index(ds) -> np.ndarray:
    """Flat indices of the valid slots of a padded layout in worker-major
    order — the exact row order ``StoredDataset.gather()`` produces.
    Single source of truth for every flatten below (the bit-identical
    guarantee hangs on this ordering).  Uniform layouts use base offsets
    ``w * capacity``; bucketed layouts use their :class:`CapacityMap`
    offsets — the enumerated row order is identical either way.
    """
    counts = np.asarray(ds.counts)
    cm = getattr(ds, "capacity_map", None)
    if cm is not None:
        offs = cm.offsets
    else:
        offs = np.arange(ds.num_workers, dtype=np.int64) * ds.capacity
    return valid_slot_index(counts, offs)


def _flat_slots(ds, v):
    """A column viewed as flat slots: bucketed columns already are
    ``(total_slots, ...)``; uniform ``(m, capacity, ...)`` columns
    reshape."""
    if getattr(ds, "capacity_map", None) is not None:
        return v
    return v.reshape((ds.num_workers * ds.capacity,) + v.shape[2:])


def flatten_dataset(ds, device_only: bool = False) -> Columns:
    """Flatten a StoredDataset's padded columns back to flat rows *without*
    a host round-trip: device-resident columns are gathered with a device
    permutation over :func:`_valid_slot_index`; host columns take the numpy
    path (skipped entirely under ``device_only``).
    """
    idx = _valid_slot_index(ds)
    idx_dev = None
    out: Columns = {}
    for k, v in ds.columns.items():
        if isinstance(v, jax.Array):
            if idx_dev is None:
                idx_dev = jnp.asarray(idx.astype(np.int32))
            out[k] = jnp.take(_flat_slots(ds, v), idx_dev, axis=0)
        elif not device_only:
            out[k] = _flat_slots(ds, np.asarray(v))[idx]
    return out


def device_flat_columns(ds) -> Optional[Columns]:
    """The device-resident subset of :func:`flatten_dataset` (engine scan
    seeds its d2d chain with these), computed without touching host cols."""
    return flatten_dataset(ds, device_only=True) or None


def device_repartition_dataset(ds, partitioner, num_partitions: int, *,
                               interpret: Optional[bool] = None,
                               use_kernel: Optional[bool] = None,
                               mode: Optional[str] = None,
                               plan_capacity: Optional[Callable] = None
                               ) -> Tuple[Columns, np.ndarray,
                                          Optional[CapacityMap]]:
    """Device-to-device repartition: device-resident StoredDataset → new
    padded device layout, no host gather/concatenate.

    Valid rows are gathered on device, the partition key is evaluated with
    the candidate's compiled key projection (jnp — stays on device), and the
    cached plan scatters straight into the new padded layout.  Only the
    pids/histogram cross to the host (the histogram sizes the capacity).
    64-bit columns ride the hybrid path as usual.

    ``plan_capacity`` (counts → Optional[CapacityMap]) lets the store
    choose a bucketed layout from the fresh histogram; returns the map it
    used (None ⇒ uniform ``(m, capacity, ...)``).
    """
    flat = flatten_dataset(ds)
    keys = partitioner.key_fn()(flat)
    pids, counts = shuffle_pids(keys, num_partitions, interpret=interpret,
                                use_kernel=use_kernel, mode=mode)
    cmap = plan_capacity(counts) if plan_capacity is not None else None
    columns = device_scatter_padded(flat, pids, counts, capacity_map=cmap,
                                    interpret=interpret,
                                    use_kernel=use_kernel, mode=mode)
    return columns, counts, cmap
