"""Device-resident repartition path (DESIGN §5).

The paper's dispatch hot spot — hash the partition key, histogram the
destinations, re-bucket every column — runs here through the fused Pallas
``hash_partition`` kernel instead of host-side numpy.  Two consumers:

* the :class:`~repro.data.partition_store.PartitionStore` device write path
  (:func:`device_scatter_padded` — scatter flat rows into the persistent
  ``(m, capacity, ...)`` layout), and
* the engine's repartition node (:func:`device_rebucket` — re-bucket a flat
  intermediate into worker segments).

Both consume the kernel's ``(pids, histogram)`` output directly, so the
histogram the store needs to size buffers is produced in the same VMEM pass
that hashes the keys.

Bit-identical guarantee: the kernel applies the same Wang hash as
``core.ir._mix_hash`` and re-bucketing is a *stable* sort by partition id
followed by a pure permutation gather — no arithmetic touches the payload —
so device results match the host numpy path exactly.  With jax's default
x64-disabled config, 64-bit payload columns cannot round-trip through jnp;
those are gathered host-side by the device-computed permutation (hybrid
gather), preserving exact bits and dtypes either way.

On CPU the kernel runs in ``interpret`` mode (auto-detected) so CI covers
the identical code path the TPU executes compiled.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hash_partition.ops import partition_ids

Columns = Dict[str, np.ndarray]


def default_interpret() -> bool:
    """Pallas kernels need interpret mode anywhere but a real TPU."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def dtype_roundtrips(dtype) -> bool:
    """True if jnp.asarray preserves this dtype under the active jax config
    (x64-disabled canonicalizes int64/float64 down — those columns must stay
    host-side to keep the bit-identical guarantee)."""
    return jnp.asarray(np.empty(0, dtype)).dtype == np.dtype(dtype)


def as_kernel_keys(keys) -> jax.Array:
    """Normalize a key column for the hash kernel.

    Mirrors ``core.ir._mix_hash``'s dtype handling exactly (float32 bits are
    reinterpreted, everything else is cast to int32 with jnp's canonical
    truncation) so kernel pids equal host pids bit-for-bit.
    """
    k = np.asarray(keys)
    if np.issubdtype(k.dtype, np.integer):
        return jnp.asarray(k.astype(np.int32))
    if k.dtype == np.float64:                     # jnp canonicalizes f64→f32
        k = k.astype(np.float32)
    if k.dtype == np.float32:
        return jnp.asarray(k.view(np.int32))
    return jnp.asarray(k.astype(np.int32))


def device_partition_ids(keys, num_partitions: int, *,
                         interpret: Optional[bool] = None,
                         use_kernel: bool = True
                         ) -> Tuple[jax.Array, jax.Array]:
    """Kernel dispatch: keys → (pids (N,) int32, histogram (m,) int32)."""
    keys = as_kernel_keys(keys)
    if keys.shape[0] == 0:           # zero-size grids crash pallas_call
        return (jnp.zeros(0, jnp.int32),
                jnp.zeros(num_partitions, jnp.int32))
    return partition_ids(keys, num_partitions,
                         interpret=_resolve_interpret(interpret),
                         use_kernel=use_kernel)


def _take(v: np.ndarray, order: jax.Array) -> np.ndarray:
    """Permutation gather — on device when the dtype round-trips, else
    host-side with the device-computed order (hybrid gather, DESIGN §5)."""
    v = np.asarray(v)
    if dtype_roundtrips(v.dtype):
        return np.asarray(jnp.take(jnp.asarray(v), order, axis=0))
    return v[np.asarray(order)]


def device_rebucket(columns: Columns, key_vals, num_partitions: int, *,
                    interpret: Optional[bool] = None,
                    use_kernel: bool = True) -> Tuple[Columns, np.ndarray]:
    """Re-bucket flat columns by hash(key) % m through the Pallas kernel.

    Returns ``(new_columns incl "__key__", counts)`` — the same contract as
    the engine's host-side shuffle (stable sort by pid + gather), with the
    per-worker counts coming from the kernel's fused histogram.
    """
    key_vals = np.asarray(key_vals).reshape(-1)
    n = key_vals.size
    if n == 0:
        out = {k: np.asarray(v).copy() for k, v in columns.items()}
        out["__key__"] = key_vals
        return out, np.zeros(num_partitions, np.int64)
    pids, hist = device_partition_ids(key_vals, num_partitions,
                                      interpret=interpret,
                                      use_kernel=use_kernel)
    order = jnp.argsort(pids, stable=True)
    out = {k: _take(v, order) for k, v in columns.items()}
    out["__key__"] = _take(key_vals, order)
    return out, np.asarray(hist).astype(np.int64)


def device_scatter_padded(flat_columns: Columns, pids, counts, *,
                          capacity: Optional[int] = None) -> Columns:
    """Scatter flat rows into the persistent ``(m, capacity, ...)`` layout.

    Consumes the kernel's ``(pids, histogram)`` pair: destination slot of row
    i is ``(pids[i], rank-of-i-within-its-partition)``, computed as a stable
    sort by pid plus an offset subtraction — one jnp scatter per column, no
    per-worker host loop.  Round-trippable columns come back device-resident
    (jax arrays); 64-bit columns are scattered host-side (hybrid).
    """
    counts_np = np.asarray(counts).astype(np.int64)
    m = int(counts_np.shape[0])
    n = int(counts_np.sum())
    cap = int(capacity) if capacity is not None else \
        (int(counts_np.max()) if n else 1)

    pids_j = jnp.asarray(np.asarray(pids).astype(np.int32))
    order = jnp.argsort(pids_j, stable=True)
    sorted_pids = jnp.take(pids_j, order)
    offsets = jnp.asarray(
        np.concatenate([[0], np.cumsum(counts_np)[:-1]]).astype(np.int32))
    rank = jnp.arange(n, dtype=jnp.int32) - jnp.take(offsets, sorted_pids)
    dest = sorted_pids.astype(jnp.int32) * cap + rank

    order_np = np.asarray(order)
    dest_np = np.asarray(dest)
    columns: Columns = {}
    for k, v in flat_columns.items():
        v = np.asarray(v)
        if dtype_roundtrips(v.dtype):
            vd = jnp.asarray(v)
            sv = jnp.take(vd, order, axis=0)
            buf = jnp.zeros((m * cap,) + v.shape[1:], vd.dtype)
            columns[k] = buf.at[dest].set(sv).reshape(
                (m, cap) + v.shape[1:])
        else:
            buf = np.zeros((m * cap,) + v.shape[1:], v.dtype)
            buf[dest_np] = v[order_np]
            columns[k] = buf.reshape((m, cap) + v.shape[1:])
    return columns
