"""Sharded, double-buffered input pipeline for LM training.

Design for 1000+ nodes: each host reads only its shard of the global batch
(host-sharded token stream), prefetches one step ahead (overlaps host compute
with device step), and tolerates stragglers by reissuing late shards
(`runtime/straggler.py`).  On this CPU container the "hosts" are simulated
by deterministic per-shard RNG streams, so restart/elastic tests can verify
exactly-once, in-order delivery after failures.
"""

from __future__ import annotations

import collections
import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    seed: int = 0


class TokenSource:
    """Deterministic synthetic token stream, seekable by (step, host).

    Seekability is the fault-tolerance primitive: a restart from checkpoint
    step S reproduces exactly the batches S, S+1, ... with no data loss or
    duplication, on any host layout (elastic re-sharding re-derives streams).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.num_hosts == 0
        self.per_host = cfg.global_batch // cfg.num_hosts

    def batch_at(self, step: int, host: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 4096 + host)
        tokens = rng.integers(0, self.cfg.vocab_size,
                              size=(self.per_host, self.cfg.seq_len),
                              dtype=np.int32)
        # next-token labels; last position wraps (synthetic stream)
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        shards = [self.batch_at(step, h) for h in range(self.cfg.num_hosts)]
        return {k: np.concatenate([s[k] for s in shards], axis=0)
                for k in shards[0]}


class PrefetchingLoader:
    """One-step-ahead prefetch: overlaps batch synthesis with device compute.

    The thread produces into a depth-1 queue; `__next__` pops.  This is the
    host-side half of compute/comm overlap — the device-side half is XLA's
    async collectives and donated buffers.
    """

    def __init__(self, source: TokenSource, start_step: int = 0,
                 prefetch_depth: int = 2):
        self.source = source
        self.step = start_step
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.source.global_batch_at(s)
            try:
                self._q.put((s, batch), timeout=1.0)
                s += 1
            except queue_mod.Full:
                continue

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue_mod.Empty:
            pass
        self._thread.join(timeout=2.0)
