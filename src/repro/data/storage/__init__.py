"""Durable columnar storage tier under the PartitionStore API (DESIGN §10).

The persistence the paper's "reused across applications" claim needs:
per-generation segment files in the padded ``(m, capacity, ...)`` layout
(zero-copy ``np.memmap`` reopen), crash-safe JSON manifests published by
write-temp-then-atomic-rename, bounded on-disk generation retention, an
Autopilot decision log, and memory-budget spill/rehydrate hooks.

Construct through the front door — ``PartitionStore(root=...)`` /
``PartitionStore.open(root)`` / ``lachesis.Session(store_path=...)`` —
rather than using :class:`DurableStore` directly.
"""

from .durable import DurableStore
from .manifest import (Manifest, RestoredPartitioner, decode_partitioner,
                       encode_partitioner, load_current)
from .segments import open_segment, read_segment, segment_valid, write_segment

__all__ = [
    "DurableStore", "Manifest", "RestoredPartitioner",
    "encode_partitioner", "decode_partitioner", "load_current",
    "open_segment", "read_segment", "segment_valid", "write_segment",
]
