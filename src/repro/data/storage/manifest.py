"""Dataset manifests — the crash-safe catalog of one dataset (DESIGN §10).

Each generation of a dataset is described by one immutable JSON manifest
(``manifest-<gen>.json``): partitioner identity (strategy + the Alg. 4
path-signature set), per-worker counts, per-column dtype/shape/byte-count
and segment file, and the generation log.  Publication is a two-step
atomic protocol:

1. segments + ``manifest-<gen>.json`` are fully written (temp + fsync +
   rename each);
2. the ``CURRENT`` pointer file is rewritten by temp-then-atomic-rename.

``CURRENT`` is the *only* mutable file, and :func:`load_current` validates
the generation it points at (manifest parses, every segment exists at its
exact byte count) before trusting it — falling back to the newest older
generation that validates.  A crash at any point therefore reopens to the
previous consistent generation, bit-identically.

Partitioners persist by *identity*, not code: Alg. 4
(:func:`~repro.core.matching.partitioning_match`) compares path-signature
sets, so a :class:`RestoredPartitioner` carrying the stored set elides
consumer shuffles across process restarts exactly like the live
:class:`~repro.core.partitioner.PartitionerCandidate` it was saved from.
It has no key graph, so it can *match* but not *dispatch* — re-keying a
restored dataset requires a live candidate from a consumer IR.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple
from urllib.parse import quote

import numpy as np

from ...core.partitioner import PartitionerCandidate
from .segments import fsync_dir, segment_valid

__all__ = ["Manifest", "RestoredPartitioner", "encode_partitioner",
           "decode_partitioner", "gen_dirname", "manifest_filename",
           "segment_filename", "publish_manifest", "load_manifest",
           "load_current", "list_generations", "atomic_write_text",
           "MANIFEST_FORMAT"]

MANIFEST_FORMAT = 1
CURRENT = "CURRENT"
_MANIFEST_RE = re.compile(r"^manifest-(\d{6})\.json$")


# ---------------------------------------------------------------------------
# Restored partitioners
# ---------------------------------------------------------------------------

@dataclass
class RestoredPartitioner(PartitionerCandidate):
    """A partitioner identity reloaded from a manifest: matchable by its
    persisted signature set (Alg. 4), but with no key graph to execute."""
    stored_signature_set: Tuple[str, ...] = ()

    def signature_set(self) -> Tuple[str, ...]:
        return tuple(self.stored_signature_set) or (self.strategy,)

    def key_fn(self):
        raise ValueError(
            "restored partitioner (loaded from a store manifest) has no key "
            "graph; repartition with a live candidate from a consumer IR")


def encode_partitioner(p: Optional[PartitionerCandidate]
                       ) -> Optional[Dict[str, Any]]:
    if p is None:
        return None
    return {"strategy": p.strategy,
            "signature_set": list(p.signature_set()),
            "source_dataset": p.source_dataset}


def decode_partitioner(d: Optional[Dict[str, Any]]
                       ) -> Optional[PartitionerCandidate]:
    if d is None:
        return None
    return RestoredPartitioner(
        graph=None, strategy=d.get("strategy", "hash"),
        source_dataset=d.get("source_dataset", ""),
        stored_signature_set=tuple(d.get("signature_set", ())))


# ---------------------------------------------------------------------------
# Manifest artifact
# ---------------------------------------------------------------------------

def gen_dirname(generation: int) -> str:
    return f"gen-{generation:06d}"


def segment_filename(column: str) -> str:
    """Filesystem-safe segment name for a column key (separators and other
    unsafe characters percent-encoded, so a key like ``"user/id"`` can
    neither crash the write nor escape the generation directory)."""
    return f"{quote(column, safe='._@+-')}.seg"


def manifest_filename(generation: int) -> str:
    return f"manifest-{generation:06d}.json"


@dataclass
class Manifest:
    """Everything needed to reopen one generation without the writer."""
    name: str
    generation: int
    num_workers: int
    capacity: int
    num_rows: int
    nbytes: int
    counts: List[int]
    partitioner: Optional[Dict[str, Any]]
    columns: Dict[str, Dict[str, Any]]   # name → {dtype, shape, nbytes, file}
    created_at: float = 0.0
    format: int = MANIFEST_FORMAT
    generation_log: List[Dict[str, Any]] = field(default_factory=list)
    #: per-partition slot capacities of a bucketed (CapacityMap) layout;
    #: None ⇒ uniform ``capacity``.  Offsets are derived (prefix sum), so
    #: older readers that drop this field still parse the manifest
    #: (from_json filters unknown keys) — format stays 1.
    capacity_map: Optional[List[int]] = None

    @classmethod
    def of_dataset(cls, ds, prev: Optional["Manifest"] = None) -> "Manifest":
        """Describe a StoredDataset (columns are recorded in the padded
        layout they already have; device columns are described via their
        host view)."""
        columns: Dict[str, Dict[str, Any]] = {}
        gdir = gen_dirname(ds.generation)
        for k, v in ds.columns.items():
            a = np.asarray(v)
            columns[k] = {"dtype": a.dtype.str, "shape": list(a.shape),
                          "nbytes": int(a.nbytes),
                          "file": f"{gdir}/{segment_filename(k)}"}
        log = list(prev.generation_log) if prev is not None else []
        log.append({"generation": int(ds.generation),
                    "rows": int(ds.num_rows),
                    "partitioner": (ds.partitioner.signature()
                                    if ds.partitioner is not None else ""),
                    "created_at": float(ds.created_at)})
        cm = getattr(ds, "capacity_map", None)
        return cls(name=ds.name, generation=int(ds.generation),
                   num_workers=int(ds.num_workers),
                   capacity=int(ds.capacity), num_rows=int(ds.num_rows),
                   nbytes=int(ds.nbytes),
                   counts=[int(c) for c in ds.counts],
                   partitioner=encode_partitioner(ds.partitioner),
                   columns=columns, created_at=float(ds.created_at),
                   generation_log=log,
                   capacity_map=([int(c) for c in cm.capacities]
                                 if cm is not None else None))

    def to_json(self) -> str:
        return json.dumps(self.__dict__, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Manifest":
        d = json.loads(text)
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})

    def validate(self, ds_dir: str) -> bool:
        """True iff every segment this manifest references exists at its
        exact byte count — the crash-recovery acceptance check.

        Cluster-sharded columns (``parts`` specs, DESIGN §14) validate by
        *coverage*, not completeness: every partition must be readable
        from at least one holding node's part, so losing any single node
        of a replicated placement never invalidates the generation."""
        if self.format > MANIFEST_FORMAT:
            return False
        for spec in self.columns.values():
            parts = spec.get("parts")
            if parts is None:
                if not segment_valid(os.path.join(ds_dir, spec["file"]),
                                     spec["nbytes"]):
                    return False
                continue
            covered = set()
            for part in parts:
                if segment_valid(os.path.join(ds_dir, part["file"]),
                                 part["nbytes"]):
                    covered.update(int(p) for p in part["partitions"])
            if not covered.issuperset(range(int(self.num_workers))):
                return False
        return True


# ---------------------------------------------------------------------------
# Atomic publication + recovery
# ---------------------------------------------------------------------------

def atomic_write_text(path: str, text: str) -> None:
    """write-temp → fsync → atomic-rename → fsync(dir): the publish
    primitive every mutable pointer in the store goes through."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(path))


def publish_manifest(ds_dir: str, manifest: Manifest) -> None:
    """Commit ``manifest``'s generation: write its immutable JSON, then
    flip CURRENT.  Callers must have fully written the segments first."""
    atomic_write_text(os.path.join(
        ds_dir, manifest_filename(manifest.generation)), manifest.to_json())
    atomic_write_text(os.path.join(ds_dir, CURRENT),
                      str(int(manifest.generation)))


def load_manifest(ds_dir: str, generation: int) -> Optional[Manifest]:
    try:
        with open(os.path.join(ds_dir, manifest_filename(generation))) as f:
            return Manifest.from_json(f.read())
    except (OSError, ValueError, TypeError, KeyError):
        return None


def list_generations(ds_dir: str) -> List[int]:
    """Generations with a manifest file on disk, ascending."""
    gens = []
    try:
        names = os.listdir(ds_dir)
    except OSError:
        return []
    for n in names:
        m = _MANIFEST_RE.match(n)
        if m:
            gens.append(int(m.group(1)))
    return sorted(gens)


def load_current(ds_dir: str) -> Optional[Manifest]:
    """The newest generation that *validates*, preferring the one CURRENT
    points at.  A truncated segment, torn manifest, or missing CURRENT all
    degrade to the most recent consistent generation (or None when the
    dataset directory holds nothing usable)."""
    candidates: List[int] = []
    try:
        with open(os.path.join(ds_dir, CURRENT)) as f:
            candidates.append(int(f.read().strip()))
    except (OSError, ValueError):
        pass
    for g in reversed(list_generations(ds_dir)):
        if g not in candidates:
            candidates.append(g)
    for g in candidates:
        m = load_manifest(ds_dir, g)
        if m is not None and m.validate(ds_dir):
            return m
    return None
