"""Segment files — one binary blob per column, per generation (DESIGN §10).

A segment is the raw C-order bytes of a column array **already in the
persistent padded layout** ``(m, capacity, ...)`` (DESIGN §2), so reading
it back is a single ``np.memmap`` — zero-copy, lazily paged, and directly
mesh-placeable (the leading axis is the worker axis) without any
re-dispatch.  The dtype/shape live in the manifest, not the file: a
segment carries payload bytes only.

Durability protocol: segments are written to a temp name, flushed and
fsync'd, then atomically renamed into place.  A segment is only *reachable*
once a manifest referencing it is published (see manifest.py) — the
manifest is the commit point — so a crash mid-write leaves at worst an
orphaned temp/partial file that validation ignores.
"""

from __future__ import annotations

import os
import threading
from typing import Tuple

import numpy as np

__all__ = ["write_segment", "open_segment", "read_segment",
           "segment_valid", "fsync_dir"]


def fsync_dir(path: str) -> None:
    """fsync a directory so renames inside it are durable (best-effort —
    not all platforms/filesystems allow opening a directory)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_segment(path: str, array: np.ndarray) -> int:
    """Persist ``array``'s bytes at ``path`` (temp + fsync + atomic rename).
    Returns the byte count written.

    The temp name is unique per writing thread: two threads racing to
    persist the same (name, generation) — a ``flush()`` against a
    concurrent spill — each complete their own temp file and the renames
    commute (same bits), instead of interleaving writes into one temp."""
    arr = np.ascontiguousarray(np.asarray(array))
    tmp = f"{path}.{os.getpid()}-{threading.get_ident()}.tmp"
    with open(tmp, "wb") as f:
        f.write(arr.tobytes())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return int(arr.nbytes)


def segment_valid(path: str, nbytes: int) -> bool:
    """True iff the segment exists with exactly the manifest's byte count —
    the truncation check crash recovery falls back on."""
    try:
        return os.path.getsize(path) == int(nbytes)
    except OSError:
        return False


def open_segment(path: str, dtype: str, shape: Tuple[int, ...]) -> np.ndarray:
    """Zero-copy read-only view of a segment (``np.memmap``).

    The result is an ndarray subclass: every consumer of the padded layout
    (gather, shuffles, device_put) works unchanged, and pages fault in
    lazily — this IS the cold-read rehydration path."""
    if any(int(s) == 0 for s in shape):
        return np.zeros(tuple(int(s) for s in shape), np.dtype(dtype))
    return np.memmap(path, dtype=np.dtype(dtype), mode="r",
                     shape=tuple(int(s) for s in shape))


def read_segment(path: str, dtype: str, shape: Tuple[int, ...]) -> np.ndarray:
    """Eager in-RAM copy of a segment (promotion out of the spilled state)."""
    return np.array(open_segment(path, dtype, shape))
