"""DurableStore — the on-disk half of a PartitionStore (DESIGN §10).

Owns one store root directory::

    root/
      catalog.json            # store identity: format, num_workers
      decisions.log           # JSONL of Autopilot-applied decisions
      datasets/<name>/
        CURRENT               # pointer file — the only mutable byte
        manifest-000007.json  # immutable, one per generation
        gen-000007/<col>.seg  # padded-layout column blobs (np.memmap-able)

Every publish goes segments → manifest → CURRENT, each step atomic
(temp + fsync + rename), so the store reopens to a consistent generation
after a crash at any point.  Retired generations are garbage-collected
past the same ``max_retired_generations`` window the in-memory store
keeps, so disk usage stays bounded under sustained Autopilot traffic.

All I/O is metered into :attr:`io_stats` — the counters the executor
surfaces per run (``EngineStats.storage_io_*``) and the Autopilot feeds
into the :class:`~repro.service.cost_model.WhatIfCostModel` I/O
calibration.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
from typing import Any, Dict, List, Optional
from urllib.parse import quote, unquote

import numpy as np

from ...obs.tracer import span as _span
from .manifest import (Manifest, atomic_write_text, decode_partitioner,
                       gen_dirname, list_generations, load_current,
                       load_manifest, manifest_filename, publish_manifest,
                       segment_filename)
from .segments import fsync_dir, open_segment, write_segment

__all__ = ["DurableStore", "CATALOG_FORMAT", "DECISIONS_SCHEMA_VERSION"]

CATALOG_FORMAT = 1
_GENERATION_LOG_CAP = 64     # manifest generation-log entries retained

#: schema version stamped into decisions.log JSONL rows.  v1 = the
#: pre-versioning applied-decision rows (no ``version`` field); v2 adds
#: the field itself plus the Autopilot's kind="why" explainability rows.
DECISIONS_SCHEMA_VERSION = 2


def _encode_name(name: str) -> str:
    """Filesystem-safe dataset directory name (reversible)."""
    return quote(name, safe="._@+-")


def _io_zero() -> Dict[str, float]:
    return {"bytes_written": 0, "write_s": 0.0,
            "bytes_read": 0, "read_s": 0.0,
            "segments_written": 0, "generations_published": 0,
            "spills": 0, "spilled_bytes": 0,
            "rehydrations": 0, "rehydrated_bytes": 0}


class DurableStore:
    """Filesystem backend for one PartitionStore root."""

    def __init__(self, root: str, *, num_workers: Optional[int] = None,
                 max_retired_generations: int = 2):
        self.root = os.path.abspath(root)
        self.max_retired_generations = int(max_retired_generations)
        self.io_stats: Dict[str, float] = _io_zero()
        # serializes io_stats read-modify-writes: many serving threads
        # meter I/O concurrently and must not lose increments
        self._io_lock = threading.Lock()
        os.makedirs(os.path.join(self.root, "datasets"), exist_ok=True)
        self.catalog = self._load_or_init_catalog(num_workers)

    # -- store-level catalog -------------------------------------------------
    @property
    def catalog_path(self) -> str:
        return os.path.join(self.root, "catalog.json")

    def _load_or_init_catalog(self, num_workers: Optional[int]) -> Dict:
        try:
            with open(self.catalog_path) as f:
                cat = json.load(f)
            if int(cat.get("format", 1)) > CATALOG_FORMAT:
                raise ValueError(
                    f"store at {self.root} uses catalog format "
                    f"{cat['format']} > supported {CATALOG_FORMAT}")
            return cat
        except OSError:
            pass
        cat = {"format": CATALOG_FORMAT,
               "num_workers": int(num_workers) if num_workers else None,
               "created_at": time.time()}
        atomic_write_text(self.catalog_path, json.dumps(cat, indent=1))
        return cat

    def io_add(self, **deltas: float) -> None:
        """Atomically add to the I/O counters (thread-safe metering)."""
        with self._io_lock:
            for k, v in deltas.items():
                self.io_stats[k] += v

    def io_snapshot(self) -> Dict[str, float]:
        with self._io_lock:
            return dict(self.io_stats)

    @property
    def num_workers(self) -> Optional[int]:
        m = self.catalog.get("num_workers")
        return int(m) if m else None

    # -- paths ---------------------------------------------------------------
    def dataset_dir(self, name: str, create: bool = False) -> str:
        d = os.path.join(self.root, "datasets", _encode_name(name))
        if create:
            os.makedirs(d, exist_ok=True)
        return d

    def dataset_names(self) -> List[str]:
        base = os.path.join(self.root, "datasets")
        try:
            return sorted(unquote(n) for n in os.listdir(base)
                          if os.path.isdir(os.path.join(base, n)))
        except OSError:
            return []

    def has_generation(self, name: str, generation: int) -> bool:
        return os.path.exists(os.path.join(
            self.dataset_dir(name), manifest_filename(generation)))

    # -- write path ----------------------------------------------------------
    def persist(self, ds, publish_current: bool = True) -> Manifest:
        """Durably publish one StoredDataset generation (idempotent for an
        already-published (name, generation) pair).

        ``publish_current=False`` writes the segments + manifest WITHOUT
        flipping the CURRENT pointer — used when materializing a retired
        (superseded) generation for spill, which must never move the
        store's visible head backwards."""
        t0 = time.perf_counter()
        with _span("durable.persist", "storage", dataset=ds.name,
                   generation=ds.generation) as sp:
            ds_dir = self.dataset_dir(ds.name, create=True)
            gdir = os.path.join(ds_dir, gen_dirname(ds.generation))
            os.makedirs(gdir, exist_ok=True)
            written = 0
            for k, v in ds.columns.items():
                written += write_segment(
                    os.path.join(gdir, segment_filename(k)), np.asarray(v))
                self.io_add(segments_written=1)
            fsync_dir(gdir)
            prev = load_manifest(ds_dir, ds.generation - 1) \
                if ds.generation > 0 else None
            man = Manifest.of_dataset(ds, prev)
            man.generation_log = man.generation_log[-_GENERATION_LOG_CAP:]
            if publish_current:
                publish_manifest(ds_dir, man)
                self._gc(ds_dir, ds.generation)
            else:
                atomic_write_text(
                    os.path.join(ds_dir, manifest_filename(man.generation)),
                    man.to_json())
            self.io_add(bytes_written=written,
                        write_s=time.perf_counter() - t0,
                        generations_published=1)
            sp.set(bytes=written)
            return man

    def _gc(self, ds_dir: str, current_gen: int) -> None:
        """Drop manifests + segment dirs older than the retention window."""
        keep_from = current_gen - self.max_retired_generations
        for g in list_generations(ds_dir):
            if g < keep_from:
                try:
                    os.remove(os.path.join(ds_dir, manifest_filename(g)))
                except OSError:
                    pass
                shutil.rmtree(os.path.join(ds_dir, gen_dirname(g)),
                              ignore_errors=True)
        fsync_dir(ds_dir)

    # -- read path -----------------------------------------------------------
    def open_columns(self, name: str, man: Manifest) -> Dict[str, np.ndarray]:
        """memmap views of every segment of ``man`` (zero-copy; pages fault
        in lazily on first touch)."""
        ds_dir = self.dataset_dir(name)
        return {k: open_segment(os.path.join(ds_dir, spec["file"]),
                                spec["dtype"], tuple(spec["shape"]))
                for k, spec in sorted(man.columns.items())}

    def load_manifest(self, name: str,
                      generation: Optional[int] = None) -> Optional[Manifest]:
        ds_dir = self.dataset_dir(name)
        if generation is None:
            return load_current(ds_dir)
        man = load_manifest(ds_dir, generation)
        if man is not None and not man.validate(ds_dir):
            return None
        return man

    def load(self, name: str, generation: Optional[int] = None):
        """Reopen ``name`` as a memmap-backed StoredDataset (the current
        generation, or a specific retained one).  None when nothing
        consistent is on disk."""
        from ..capacity import CapacityMap            # deferred: cycle
        from ..partition_store import StoredDataset   # deferred: cycle
        man = self.load_manifest(name, generation)
        if man is None:
            return None
        t0 = time.perf_counter()
        cols = self.open_columns(name, man)
        self.io_add(read_s=time.perf_counter() - t0)
        cm = getattr(man, "capacity_map", None)
        return StoredDataset(
            name=man.name, columns=cols,
            counts=np.asarray(man.counts, np.int64),
            partitioner=decode_partitioner(man.partitioner),
            num_rows=int(man.num_rows), nbytes=int(man.nbytes),
            created_at=float(man.created_at),
            generation=int(man.generation),
            capacity_map=CapacityMap.of(cm) if cm is not None else None)

    def load_all(self) -> Dict[str, Any]:
        out = {}
        for name in self.dataset_names():
            ds = self.load(name)
            if ds is not None:
                out[name] = ds
        return out

    # -- decision log (Autopilot) --------------------------------------------
    @property
    def decisions_path(self) -> str:
        return os.path.join(self.root, "decisions.log")

    def log_decision(self, record: Dict[str, Any]) -> None:
        """Append one decision record (single-write JSONL line).

        Rows are stamped with the writer's schema version
        (:data:`DECISIONS_SCHEMA_VERSION`) unless the caller set one;
        :meth:`decisions` treats missing versions as v1 (pre-versioning
        writers) and skips-but-reports rows from a future version."""
        record = dict(record)
        record.setdefault("version", DECISIONS_SCHEMA_VERSION)
        with open(self.decisions_path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def decisions(self) -> List[Dict[str, Any]]:
        """Parsed decisions.log rows this reader understands (versions ≤
        :data:`DECISIONS_SCHEMA_VERSION`; missing version ⇒ v1).  Rows
        from a future schema are skipped, counted in
        ``self.skipped_decisions`` and warned about once per load — a
        downgraded reader degrades instead of crashing."""
        out: List[Dict[str, Any]] = []
        skipped = 0
        try:
            with open(self.decisions_path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except ValueError:
                        continue        # torn final line after a crash
                    try:
                        v = int(rec.get("version", 1))
                    except (TypeError, ValueError):
                        v = DECISIONS_SCHEMA_VERSION + 1   # unparseable
                    if v > DECISIONS_SCHEMA_VERSION:
                        skipped += 1
                        continue
                    out.append(rec)
        except OSError:
            pass
        self.skipped_decisions = skipped
        if skipped:
            warnings.warn(
                f"decisions.log: skipped {skipped} row(s) with schema "
                f"version > {DECISIONS_SCHEMA_VERSION} (written by a newer "
                "build); readable rows were loaded", RuntimeWarning,
                stacklevel=2)
        return out
