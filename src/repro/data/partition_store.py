"""Persistent partitioned storage — the Pangea-storage analogue (paper §4).

A :class:`PartitionStore` holds named columnar datasets laid out across ``m``
logical workers.  The layout is the *persistent partitioning*: column arrays
are shaped ``(m, capacity, ...)`` with a per-worker ``counts`` vector, so a
consumer whose desired partitioner matches the stored one operates strictly
worker-locally (no shuffle).  On a TPU pod the leading axis maps onto the
mesh via ``NamedSharding(mesh, P("data"))`` — see core/sharding_bridge.

TPU adaptation (DESIGN §2): objects → fixed-capacity padded rows; skew shows
up as padding waste, penalized by the ``key_distribution`` feature.

Backends (DESIGN §5): ``backend="host"`` (default) dispatches with numpy
(one vectorized counting-sort placement per write, no per-worker Python
loop); ``backend="device"`` holds columns device-resident (jnp) behind the
same ``(m, capacity)`` layout, dispatching through the cached single-pass
shuffle plans (hash → counting-sort → packed scatter) and repartitioning
device-to-device when the source dataset is device-backed.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.partitioner import (HASH, PartitionerCandidate, RANDOM,
                                ROUND_ROBIN)
from .device_repartition import (device_repartition_dataset,
                                 device_scatter_padded,
                                 host_counting_sort_dest, shuffle_pids)


Columns = Dict[str, np.ndarray]

#: kept for backward compatibility; the authoritative list lives in the
#: BackendRegistry (repro.core.backends.REGISTRY)
BACKENDS = ("host", "device")


class RetiredGenerationError(KeyError):
    """A specific, still-retained generation was requested but has left
    the bounded retention window (``max_retired_generations``).  Distinct
    from a plain ``KeyError`` (unknown dataset name) so callers that pin
    generations — the planner — can retry on exactly this condition."""

# one vectorized counting-sort placement shared by all columns, replacing
# the per-worker Python copy loop (lives in device_repartition so the
# hostperm shuffle plans share the exact same placement)
_counting_sort_dest = host_counting_sort_dest


def _presorted_dest(counts: np.ndarray, cap: int) -> np.ndarray:
    """Same placement for rows already segmented per worker (write_layout):
    no sort needed, the worker id is implied by the segmentation."""
    m = counts.shape[0]
    pids = np.repeat(np.arange(m, dtype=np.int64), counts)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(pids.shape[0], dtype=np.int64) - offsets[pids]
    return pids * cap + rank


@dataclass
class StoredDataset:
    """One immutable generation of a named dataset.

    Column arrays are never mutated in place after construction; a layout
    change installs a NEW StoredDataset and atomically flips the store's
    name → generation pointer (DESIGN §8).  A reader holding this object
    therefore always sees one consistent generation, never a half-shuffled
    table, even while a background repartition swaps the pointer."""
    name: str
    columns: Columns                   # each (m, capacity, ...)
    counts: np.ndarray                 # (m,) valid rows per worker
    partitioner: Optional[PartitionerCandidate]
    num_rows: int
    nbytes: int
    created_at: float = field(default_factory=time.time)
    generation: int = 0

    @property
    def num_workers(self) -> int:
        return int(self.counts.shape[0])

    @property
    def capacity(self) -> int:
        return int(next(iter(self.columns.values())).shape[1])

    def skew(self) -> float:
        """max/mean partition fill — load-balance diagnostic."""
        mean = max(self.counts.mean(), 1e-9)
        return float(self.counts.max() / mean)

    @property
    def backend(self) -> str:
        """"device" when any column is device-resident (a jax array)."""
        import jax
        return "device" if any(isinstance(v, jax.Array)
                               for v in self.columns.values()) else "host"

    def gather(self) -> Columns:
        """Materialize back to flat rows (host-side, used by shuffles)."""
        out: Columns = {}
        for k, v in self.columns.items():
            v = np.asarray(v)
            parts = [v[w, :self.counts[w]] for w in range(self.num_workers)]
            out[k] = np.concatenate(parts, axis=0)
        return out

    def to_host(self) -> "StoredDataset":
        """Copy with every column materialized as numpy (layout unchanged)."""
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        return StoredDataset(name=self.name, columns=cols,
                             counts=self.counts, partitioner=self.partitioner,
                             num_rows=self.num_rows, nbytes=self.nbytes,
                             created_at=self.created_at,
                             generation=self.generation)


class PartitionStore:
    def __init__(self, num_workers: int = 8, backend: str = "host",
                 interpret: Optional[bool] = None,
                 max_retired_generations: int = 2,
                 registry=None):
        from ..core.backends import resolve_backend
        self.m = num_workers
        # UnknownBackendError on typos; `registry` (default: the global
        # one) lets a Session thread its own registry through, so custom
        # backends registered there resolve here too
        b = resolve_backend(backend, registry)
        self.backend = b.name
        # capability, not name: a registered custom backend with
        # device_resident=True gets device-resident columns too
        self._device_resident = b.device_resident
        self.interpret = interpret      # None → auto (interpret off-TPU)
        self.datasets: Dict[str, StoredDataset] = {}
        self.write_log: List[Dict[str, Any]] = []
        # generation machinery (DESIGN §8): `datasets` maps each name to its
        # CURRENT generation; superseded generations are retained (bounded)
        # so in-flight readers and audits can still resolve them by number.
        self.max_retired_generations = max_retired_generations
        self._retired: Dict[str, List[StoredDataset]] = {}
        self._swap_lock = threading.Lock()

    def _install(self, name: str, ds: StoredDataset) -> StoredDataset:
        """Atomically make ``ds`` the current generation of ``name``.

        The flip is a single dict assignment under a lock; readers that
        already hold the previous StoredDataset keep reading it unchanged
        (generations are immutable)."""
        with self._swap_lock:
            prev = self.datasets.get(name)
            if prev is not None:
                ds.generation = prev.generation + 1
                retired = self._retired.setdefault(name, [])
                retired.append(prev)
                if len(retired) > self.max_retired_generations:
                    del retired[:len(retired)
                                - self.max_retired_generations]
            self.datasets[name] = ds
        return ds

    def generation_of(self, name: str) -> int:
        return self.datasets[name].generation

    # -- write path (storage-time partitioning) ------------------------------
    def write(self, name: str, data: Columns,
              partitioner: Optional[PartitionerCandidate] = None,
              seed: int = 0) -> StoredDataset:
        """Dispatch each row to a worker via ``g(d_i)`` and persist."""
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        if partitioner is None:
            partitioner = PartitionerCandidate(graph=None, strategy=ROUND_ROBIN)

        if self._device_resident:
            columns, counts = self._dispatch_device(data, partitioner, n, seed)
        else:
            columns, counts = self._dispatch_host(data, partitioner, n, seed)

        nbytes = int(sum(np.asarray(v).nbytes for v in data.values()))
        ds = StoredDataset(name=name, columns=columns,
                           counts=counts.astype(np.int64),
                           partitioner=partitioner, num_rows=n, nbytes=nbytes)
        self._install(name, ds)
        self.write_log.append({
            "name": name, "rows": n, "bytes": nbytes,
            "strategy": partitioner.strategy,
            "latency": time.perf_counter() - t0,
            "skew": ds.skew(),
            "generation": ds.generation,
        })
        return ds

    # -- dispatch backends ---------------------------------------------------
    def _host_pids(self, data: Columns, partitioner: PartitionerCandidate,
                   n: int, seed: int) -> np.ndarray:
        pids = np.asarray(partitioner.partition_ids(data, self.m)) \
            if partitioner.strategy != RANDOM else \
            np.random.default_rng(seed).integers(0, self.m, size=n)
        return np.asarray(pids, np.int64)

    def _dispatch_host(self, data, partitioner, n, seed):
        """Host-side numpy dispatch: one counting-sort placement, then a
        single vectorized scatter per column (no per-worker Python loop)."""
        pids = self._host_pids(data, partitioner, n, seed)
        counts = np.bincount(pids, minlength=self.m)
        cap = int(counts.max()) if n else 1
        dest = _counting_sort_dest(pids, counts, cap)
        columns: Columns = {}
        for k, v in data.items():
            v = np.asarray(v)
            buf = np.zeros((self.m * cap,) + v.shape[1:], v.dtype)
            buf[dest] = v
            columns[k] = buf.reshape((self.m, cap) + v.shape[1:])
        return columns, counts

    def _dispatch_device(self, data, partitioner, n, seed):
        """Device dispatch (DESIGN §5): hash keys through the Pallas kernel,
        re-bucket with a jax scatter consuming its (pids, histogram) output.
        Keyless/range strategies keep their host pid computation but still
        scatter on device, so the stored columns are device-resident."""
        if partitioner.strategy == HASH and partitioner.graph is not None:
            keys = partitioner.key_fn()(data)
            pids, counts = shuffle_pids(keys, self.m,
                                        interpret=self.interpret)
        else:
            pids = self._host_pids(data, partitioner, n, seed)
            counts = np.bincount(pids, minlength=self.m).astype(np.int64)
        columns = device_scatter_padded(data, pids, counts,
                                        interpret=self.interpret)
        return columns, counts

    def write_layout(self, name: str, flat_columns: Columns,
                     counts: np.ndarray,
                     partitioner: Optional[PartitionerCandidate],
                     device_columns: Optional[Columns] = None
                     ) -> StoredDataset:
        """Persist an ALREADY-partitioned table (flat columns segmented per
        worker by ``counts``) without re-dispatching — used when a workload
        materializes an output whose layout was produced by its own
        partition nodes (e.g. iterative PageRank writing updated ranks).

        ``device_columns`` — device-resident flats from an upstream device
        shuffle (engine d2d chain); the device scatter consumes them in
        place of re-uploading the matching host columns."""
        counts = np.asarray(counts, np.int64)
        n = int(counts.sum())
        cap = int(counts.max()) if n else 1
        if self._device_resident:
            # rows are already segmented per worker ⇒ pids are implied
            pids = np.repeat(np.arange(self.m, dtype=np.int32), counts)
            columns = device_scatter_padded(flat_columns, pids, counts,
                                            capacity=cap,
                                            interpret=self.interpret,
                                            device_columns=device_columns)
        else:
            dest = _presorted_dest(counts, cap)
            columns = {}
            for k, v in flat_columns.items():
                v = np.asarray(v)
                buf = np.zeros((self.m * cap,) + v.shape[1:], v.dtype)
                buf[dest] = v
                columns[k] = buf.reshape((self.m, cap) + v.shape[1:])
        nbytes = int(sum(np.asarray(v).nbytes for v in flat_columns.values()))
        ds = StoredDataset(name=name, columns=columns, counts=counts,
                           partitioner=partitioner, num_rows=n, nbytes=nbytes)
        return self._install(name, ds)

    # -- read path -------------------------------------------------------------
    def read(self, name: str,
             generation: Optional[int] = None) -> StoredDataset:
        """Current generation of ``name``; pass ``generation`` to resolve a
        specific (possibly superseded, still-retained) one."""
        ds = self.datasets[name]
        if generation is None or ds.generation == generation:
            return ds
        for old in reversed(self._retired.get(name, [])):
            if old.generation == generation:
                return old
        raise RetiredGenerationError(
            f"{name}@gen{generation} not found "
            f"(current gen {ds.generation}, retains last "
            f"{self.max_retired_generations})")

    def stored_partitioners(self) -> Dict[str, Optional[PartitionerCandidate]]:
        return {n: d.partitioner for n, d in self.datasets.items()}

    # -- shuffle (the operation Lachesis exists to avoid) ------------------------
    def repartition(self, ds: StoredDataset,
                    partitioner: PartitionerCandidate,
                    name: Optional[str] = None,
                    mesh=None, swap: bool = False) -> Tuple[StoredDataset, int]:
        """Full shuffle.  Returns (new ds, bytes moved).

        Bytes moved = (m-1)/m of the dataset on average (every row whose new
        worker differs from its current one crosses the network).

        Device-to-device fast path (DESIGN §5): when both the store and the
        dataset are device-backed and the target is a keyed hash
        partitioner, the shuffle runs entirely on device — flatten by a
        device gather, hash with the compiled key projection, counting-sort
        scatter into the new layout — with no host ``gather()``/concatenate.
        Pass ``mesh`` to commit the result back onto the mesh
        (``sharding_bridge.device_put_dataset``) so repartitioned datasets
        stay mesh-placed.

        ``swap=True`` (DESIGN §8) rewrites the dataset *in place* as a new
        generation under its own name: the whole shuffle materializes off
        to the side, then one atomic pointer flip publishes it.  Concurrent
        readers holding the previous generation keep a consistent view."""
        t0 = time.perf_counter()
        moved = int(ds.nbytes * (self.m - 1) / self.m)
        name = name or (ds.name if swap else ds.name + "@reparted")
        if mesh is not None:
            from ..core.sharding_bridge import device_put_dataset
        if (self._device_resident and ds.backend == "device"
                and partitioner.strategy == HASH
                and partitioner.graph is not None):
            columns, counts = device_repartition_dataset(
                ds, partitioner, self.m, interpret=self.interpret)
            new = StoredDataset(name=name, columns=columns, counts=counts,
                                partitioner=partitioner,
                                num_rows=int(counts.sum()),
                                nbytes=ds.nbytes)
            if mesh is not None:
                new = device_put_dataset(mesh, new)
            self._install(name, new)
            self.write_log.append({
                "name": name, "rows": new.num_rows, "bytes": new.nbytes,
                "strategy": partitioner.strategy,
                "latency": time.perf_counter() - t0,
                "skew": new.skew(), "path": "d2d",
                "generation": new.generation,
            })
        else:
            flat = ds.gather()
            new = self.write(name, flat, partitioner)
            if mesh is not None:
                # same generation, mesh-placed columns — re-publish only if
                # no newer generation landed while we were placing (CAS)
                new = device_put_dataset(mesh, new)
                with self._swap_lock:
                    cur = self.datasets.get(name)
                    if cur is not None and cur.generation == new.generation:
                        self.datasets[name] = new
        return new, moved
