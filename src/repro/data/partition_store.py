"""Persistent partitioned storage — the Pangea-storage analogue (paper §4).

A :class:`PartitionStore` holds named columnar datasets laid out across ``m``
logical workers.  The layout is the *persistent partitioning*: column arrays
are shaped ``(m, capacity, ...)`` with a per-worker ``counts`` vector, so a
consumer whose desired partitioner matches the stored one operates strictly
worker-locally (no shuffle).  On a TPU pod the leading axis maps onto the
mesh via ``NamedSharding(mesh, P("data"))`` — see core/sharding_bridge.

TPU adaptation (DESIGN §2): objects → fixed-capacity padded rows; skew shows
up as padding waste, penalized by the ``key_distribution`` feature.

Backends (DESIGN §5): ``backend="host"`` (default) dispatches with numpy;
``backend="device"`` holds columns device-resident (jnp) behind the same
``(m, capacity)`` layout, hashing keys through the fused Pallas
``hash_partition`` kernel and scattering rows with a jax-backed re-bucket
that consumes the kernel's ``(pids, histogram)`` output.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.partitioner import (HASH, PartitionerCandidate, RANDOM,
                                ROUND_ROBIN)
from .device_repartition import device_partition_ids, device_scatter_padded


Columns = Dict[str, np.ndarray]

BACKENDS = ("host", "device")


@dataclass
class StoredDataset:
    name: str
    columns: Columns                   # each (m, capacity, ...)
    counts: np.ndarray                 # (m,) valid rows per worker
    partitioner: Optional[PartitionerCandidate]
    num_rows: int
    nbytes: int
    created_at: float = field(default_factory=time.time)

    @property
    def num_workers(self) -> int:
        return int(self.counts.shape[0])

    @property
    def capacity(self) -> int:
        return int(next(iter(self.columns.values())).shape[1])

    def skew(self) -> float:
        """max/mean partition fill — load-balance diagnostic."""
        mean = max(self.counts.mean(), 1e-9)
        return float(self.counts.max() / mean)

    @property
    def backend(self) -> str:
        """"device" when any column is device-resident (a jax array)."""
        import jax
        return "device" if any(isinstance(v, jax.Array)
                               for v in self.columns.values()) else "host"

    def gather(self) -> Columns:
        """Materialize back to flat rows (host-side, used by shuffles)."""
        out: Columns = {}
        for k, v in self.columns.items():
            v = np.asarray(v)
            parts = [v[w, :self.counts[w]] for w in range(self.num_workers)]
            out[k] = np.concatenate(parts, axis=0)
        return out

    def to_host(self) -> "StoredDataset":
        """Copy with every column materialized as numpy (layout unchanged)."""
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        return StoredDataset(name=self.name, columns=cols,
                             counts=self.counts, partitioner=self.partitioner,
                             num_rows=self.num_rows, nbytes=self.nbytes,
                             created_at=self.created_at)


class PartitionStore:
    def __init__(self, num_workers: int = 8, backend: str = "host",
                 interpret: Optional[bool] = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.m = num_workers
        self.backend = backend
        self.interpret = interpret      # None → auto (interpret off-TPU)
        self.datasets: Dict[str, StoredDataset] = {}
        self.write_log: List[Dict[str, Any]] = []

    # -- write path (storage-time partitioning) ------------------------------
    def write(self, name: str, data: Columns,
              partitioner: Optional[PartitionerCandidate] = None,
              seed: int = 0) -> StoredDataset:
        """Dispatch each row to a worker via ``g(d_i)`` and persist."""
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        if partitioner is None:
            partitioner = PartitionerCandidate(graph=None, strategy=ROUND_ROBIN)

        if self.backend == "device":
            columns, counts = self._dispatch_device(data, partitioner, n, seed)
        else:
            columns, counts = self._dispatch_host(data, partitioner, n, seed)

        nbytes = int(sum(np.asarray(v).nbytes for v in data.values()))
        ds = StoredDataset(name=name, columns=columns,
                           counts=counts.astype(np.int64),
                           partitioner=partitioner, num_rows=n, nbytes=nbytes)
        self.datasets[name] = ds
        self.write_log.append({
            "name": name, "rows": n, "bytes": nbytes,
            "strategy": partitioner.strategy,
            "latency": time.perf_counter() - t0,
            "skew": ds.skew(),
        })
        return ds

    # -- dispatch backends ---------------------------------------------------
    def _host_pids(self, data: Columns, partitioner: PartitionerCandidate,
                   n: int, seed: int) -> np.ndarray:
        pids = np.asarray(partitioner.partition_ids(data, self.m)) \
            if partitioner.strategy != RANDOM else \
            np.random.default_rng(seed).integers(0, self.m, size=n)
        return np.asarray(pids, np.int64)

    def _dispatch_host(self, data, partitioner, n, seed):
        """Host-side numpy dispatch: argsort by pid + per-worker copy."""
        pids = self._host_pids(data, partitioner, n, seed)
        order = np.argsort(pids, kind="stable")
        sorted_pids = pids[order]
        counts = np.bincount(sorted_pids, minlength=self.m)
        cap = int(counts.max()) if n else 1
        offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
        columns: Columns = {}
        for k, v in data.items():
            v = np.asarray(v)
            buf = np.zeros((self.m, cap) + v.shape[1:], v.dtype)
            sv = v[order]
            for w in range(self.m):
                c = counts[w]
                if c:
                    buf[w, :c] = sv[offsets[w]:offsets[w] + c]
            columns[k] = buf
        return columns, counts

    def _dispatch_device(self, data, partitioner, n, seed):
        """Device dispatch (DESIGN §5): hash keys through the Pallas kernel,
        re-bucket with a jax scatter consuming its (pids, histogram) output.
        Keyless/range strategies keep their host pid computation but still
        scatter on device, so the stored columns are device-resident."""
        if partitioner.strategy == HASH and partitioner.graph is not None:
            keys = partitioner.key_fn()(data)
            pids, hist = device_partition_ids(keys, self.m,
                                              interpret=self.interpret)
            counts = np.asarray(hist).astype(np.int64)
        else:
            pids = self._host_pids(data, partitioner, n, seed)
            counts = np.bincount(pids, minlength=self.m).astype(np.int64)
        columns = device_scatter_padded(data, pids, counts)
        return columns, counts

    def write_layout(self, name: str, flat_columns: Columns,
                     counts: np.ndarray,
                     partitioner: Optional[PartitionerCandidate]
                     ) -> StoredDataset:
        """Persist an ALREADY-partitioned table (flat columns segmented per
        worker by ``counts``) without re-dispatching — used when a workload
        materializes an output whose layout was produced by its own
        partition nodes (e.g. iterative PageRank writing updated ranks)."""
        counts = np.asarray(counts, np.int64)
        n = int(counts.sum())
        cap = int(counts.max()) if n else 1
        if self.backend == "device":
            # rows are already segmented per worker ⇒ pids are implied
            pids = np.repeat(np.arange(self.m, dtype=np.int32), counts)
            columns = device_scatter_padded(flat_columns, pids, counts,
                                            capacity=cap)
        else:
            offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
            columns = {}
            for k, v in flat_columns.items():
                v = np.asarray(v)
                buf = np.zeros((self.m, cap) + v.shape[1:], v.dtype)
                for w in range(self.m):
                    c = counts[w]
                    if c:
                        buf[w, :c] = v[offsets[w]:offsets[w] + c]
                columns[k] = buf
        nbytes = int(sum(np.asarray(v).nbytes for v in flat_columns.values()))
        ds = StoredDataset(name=name, columns=columns, counts=counts,
                           partitioner=partitioner, num_rows=n, nbytes=nbytes)
        self.datasets[name] = ds
        return ds

    # -- read path -------------------------------------------------------------
    def read(self, name: str) -> StoredDataset:
        return self.datasets[name]

    def stored_partitioners(self) -> Dict[str, Optional[PartitionerCandidate]]:
        return {n: d.partitioner for n, d in self.datasets.items()}

    # -- shuffle (the operation Lachesis exists to avoid) ------------------------
    def repartition(self, ds: StoredDataset,
                    partitioner: PartitionerCandidate,
                    name: Optional[str] = None) -> Tuple[StoredDataset, int]:
        """Full shuffle: gather + re-bucket.  Returns (new ds, bytes moved).

        Bytes moved = (m-1)/m of the dataset on average (every row whose new
        worker differs from its current one crosses the network)."""
        flat = ds.gather()
        moved = int(ds.nbytes * (self.m - 1) / self.m)
        new = self.write(name or ds.name + "@reparted", flat, partitioner)
        return new, moved
