"""Persistent partitioned storage — the Pangea-storage analogue (paper §4).

A :class:`PartitionStore` holds named columnar datasets laid out across ``m``
logical workers.  The layout is the *persistent partitioning*: column arrays
are shaped ``(m, capacity, ...)`` with a per-worker ``counts`` vector, so a
consumer whose desired partitioner matches the stored one operates strictly
worker-locally (no shuffle).  On a TPU pod the leading axis maps onto the
mesh via ``NamedSharding(mesh, P("data"))`` — see core/sharding_bridge.

TPU adaptation (DESIGN §2): objects → fixed-capacity padded rows; skew shows
up as padding waste, penalized by the ``key_distribution`` feature.

Backends (DESIGN §5): ``backend="host"`` (default) dispatches with numpy
(one vectorized counting-sort placement per write, no per-worker Python
loop); ``backend="device"`` holds columns device-resident (jnp) behind the
same ``(m, capacity)`` layout, dispatching through the cached single-pass
shuffle plans (hash → counting-sort → packed scatter) and repartitioning
device-to-device when the source dataset is device-backed.

Durability (DESIGN §10): pass ``root=`` to back the store with the
:mod:`~repro.data.storage` tier — every published generation is written as
per-column segment files (already in the padded layout, so reopening is a
zero-copy ``np.memmap``) under a crash-safe manifest; a fresh process
reattaches with :meth:`PartitionStore.open` (or
``lachesis.Session(store_path=...)``) and consumers elide their shuffles
against layouts a previous application paid for.  ``memory_budget_bytes``
turns on the eviction loop: cold datasets spill to their segments, reads
lazily rehydrate, and a device-resident store prefetches host→device.
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.partitioner import (HASH, PartitionerCandidate, RANDOM,
                                ROUND_ROBIN)
from ..obs.tracer import span as _span
from .capacity import CapacityMap, plan_capacity_map, valid_slot_index
from .device_repartition import (device_repartition_dataset,
                                 device_scatter_padded, dtype_roundtrips,
                                 flatten_dataset, host_counting_sort_dest,
                                 shuffle_pids)


Columns = Dict[str, np.ndarray]

#: kept for backward compatibility; the authoritative list lives in the
#: BackendRegistry (repro.core.backends.REGISTRY)
BACKENDS = ("host", "device")

#: write_log entries retained verbatim; older entries fold into the
#: monotone ``write_totals`` aggregates (satellite of DESIGN §10)
DEFAULT_WRITE_LOG_CAP = 256


class RetiredGenerationError(KeyError):
    """A specific, still-retained generation was requested but has left
    the bounded retention window (``max_retired_generations``).  Distinct
    from a plain ``KeyError`` (unknown dataset name) so callers that pin
    generations — the planner — can retry on exactly this condition."""

# one vectorized counting-sort placement shared by all columns, replacing
# the per-worker Python copy loop (lives in device_repartition so the
# hostperm shuffle plans share the exact same placement)
_counting_sort_dest = host_counting_sort_dest


def _presorted_dest(counts: np.ndarray, cap: int,
                    dest_offsets: Optional[np.ndarray] = None) -> np.ndarray:
    """Same placement for rows already segmented per worker (write_layout):
    no sort needed, the worker id is implied by the segmentation.  A
    bucketed layout passes its per-partition ``dest_offsets``."""
    m = counts.shape[0]
    pids = np.repeat(np.arange(m, dtype=np.int64), counts)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(pids.shape[0], dtype=np.int64) - offsets[pids]
    if dest_offsets is None:
        return pids * cap + rank
    return np.asarray(dest_offsets, dtype=np.int64)[pids] + rank


@dataclass
class StoredDataset:
    """One immutable generation of a named dataset.

    Column arrays are never mutated in place after construction; a layout
    change installs a NEW StoredDataset and atomically flips the store's
    name → generation pointer (DESIGN §8).  A reader holding this object
    therefore always sees one consistent generation, never a half-shuffled
    table, even while a background repartition swaps the pointer.

    (The eviction loop may swap a column's *container* — in-RAM ndarray ⇄
    read-only memmap of its persisted segment — which is bit-identical by
    construction, so the immutable-values contract holds for readers.)

    Layouts: with ``capacity_map=None`` (the default), columns are the
    uniform padded ``(m, capacity, ...)`` grid.  With a
    :class:`~repro.data.capacity.CapacityMap`, columns are *flat*
    ``(total_slots, ...)`` and partition ``i`` occupies the slot range
    ``[offsets[i], offsets[i] + capacities[i])`` — the skew-adaptive
    layout (DESIGN §12).  ``gather()`` produces the identical row order
    for both."""
    name: str
    columns: Columns                   # (m, capacity, ...) or (slots, ...)
    counts: np.ndarray                 # (m,) valid rows per worker
    partitioner: Optional[PartitionerCandidate]
    num_rows: int
    nbytes: int
    created_at: float = field(default_factory=time.time)
    generation: int = 0
    capacity_map: Optional[CapacityMap] = None

    @property
    def num_workers(self) -> int:
        return int(self.counts.shape[0])

    @property
    def capacity(self) -> int:
        if self.capacity_map is not None:
            caps = self.capacity_map.capacities
            return int(caps.max()) if caps.size else 0
        return int(next(iter(self.columns.values())).shape[1])

    def slot_capacities(self) -> np.ndarray:
        """(m,) per-partition slot capacities (uniform ⇒ all equal)."""
        if self.capacity_map is not None:
            return self.capacity_map.capacities
        return np.full(self.num_workers, self.capacity, dtype=np.int64)

    def slot_offsets(self) -> np.ndarray:
        """(m,) flat-slot base offset of each partition."""
        if self.capacity_map is not None:
            return self.capacity_map.offsets
        return np.arange(self.num_workers, dtype=np.int64) * self.capacity

    @property
    def total_slots(self) -> int:
        if self.capacity_map is not None:
            return self.capacity_map.total_slots
        return self.num_workers * self.capacity

    @property
    def padded_bytes(self) -> int:
        """Bytes actually occupied by the padded layout (incl. padding)."""
        return int(sum(int(v.nbytes) for v in self.columns.values()))

    @property
    def valid_bytes(self) -> int:
        """Bytes of real rows inside the padded layout."""
        slots = self.total_slots
        if slots <= 0:
            return 0
        return int(self.padded_bytes * (self.num_rows / slots))

    def padding_waste(self) -> int:
        """Bytes spent on padding alone — what skew costs this layout."""
        return max(self.padded_bytes - self.valid_bytes, 0)

    def skew(self) -> float:
        """max/mean partition fill — load-balance diagnostic."""
        mean = max(self.counts.mean(), 1e-9)
        return float(self.counts.max() / mean)

    @property
    def backend(self) -> str:
        """"device" when any column is device-resident (a jax array)."""
        return "device" if any(isinstance(v, jax.Array)
                               for v in self.columns.values()) else "host"

    @property
    def spilled(self) -> bool:
        """True when every column is a disk-backed memmap view (the
        eviction loop's cold state — reads page in lazily).  Zero-size
        columns hold no memory and cannot be memmapped, so they don't
        count against the cold state."""
        cols = [v for v in self.columns.values() if v.size]
        return bool(self.columns) and all(isinstance(v, np.memmap)
                                          for v in cols)

    def gather(self) -> Columns:
        """Materialize back to flat rows (host-side, used by shuffles):
        one boolean-mask take over the padded layout per column — the
        row-major (worker-major) mask reproduces the per-worker
        concatenation order exactly.  A bucketed layout takes the same
        worker-major rows through its slot-offset index, so the output is
        bit-identical across layouts."""
        counts = np.asarray(self.counts)
        if self.capacity_map is not None:
            idx = valid_slot_index(counts, self.capacity_map.offsets)
            return {k: np.asarray(v)[idx] for k, v in self.columns.items()}
        m, cap = self.num_workers, self.capacity
        mask = (np.arange(cap) < counts[:, None]).reshape(-1)
        out: Columns = {}
        for k, v in self.columns.items():
            v = np.asarray(v)
            out[k] = v.reshape((m * cap,) + v.shape[2:])[mask]
        return out

    def to_host(self) -> "StoredDataset":
        """Copy with every column materialized as numpy (layout unchanged)."""
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        return StoredDataset(name=self.name, columns=cols,
                             counts=self.counts, partitioner=self.partitioner,
                             num_rows=self.num_rows, nbytes=self.nbytes,
                             created_at=self.created_at,
                             generation=self.generation,
                             capacity_map=self.capacity_map)


class PartitionStore:
    def __init__(self, num_workers: int = 8, backend: str = "host",
                 interpret: Optional[bool] = None,
                 max_retired_generations: int = 2,
                 registry=None,
                 root: Optional[str] = None,
                 memory_budget_bytes: Optional[int] = None,
                 autoflush: bool = True,
                 write_log_cap: int = DEFAULT_WRITE_LOG_CAP,
                 adaptive_capacity: bool = False,
                 capacity_threshold: float = 0.75,
                 cluster=None):
        from ..core.backends import resolve_backend
        # UnknownBackendError on typos; `registry` (default: the global
        # one) lets a Session thread its own registry through, so custom
        # backends registered there resolve here too
        b = resolve_backend(backend, registry)
        self.backend = b.name
        # capability, not name: a registered custom backend with
        # device_resident=True gets device-resident columns too
        self._device_resident = b.device_resident
        self._storage_prefetch = b.storage_prefetch
        self.interpret = interpret      # None → auto (interpret off-TPU)
        # skew-adaptive layout (DESIGN §12): opt-in — when on, writes whose
        # histogram is skewed enough get a bucketed CapacityMap layout
        # instead of the uniform worst-case capacity
        self.adaptive_capacity = bool(adaptive_capacity)
        self.capacity_threshold = float(capacity_threshold)
        self.datasets: Dict[str, StoredDataset] = {}
        self.write_log: List[Dict[str, Any]] = []
        self.write_log_cap = int(write_log_cap)
        #: monotone aggregates over ALL writes (including entries evicted
        #: from the bounded write_log) — benchmarks read these
        self.write_totals: Dict[str, float] = {
            "entries": 0, "rows": 0, "bytes": 0, "latency_s": 0.0,
            "evicted": 0, "padded_bytes": 0, "valid_bytes": 0,
            "max_skew": 0.0}
        # generation machinery (DESIGN §8): `datasets` maps each name to its
        # CURRENT generation; superseded generations are retained (bounded)
        # so in-flight readers and audits can still resolve them by number.
        self.max_retired_generations = max_retired_generations
        self._retired: Dict[str, List[StoredDataset]] = {}
        # Concurrency contract (DESIGN §11): the name→StoredDataset pointer
        # flip is one dict assignment, so READS ARE LOCK-FREE — a reader
        # resolves the current generation with a plain dict lookup and then
        # owns an immutable object.  ``_swap_lock`` is the writer side: it
        # serializes pointer flips, retired-list maintenance and container
        # swaps (spill/prefetch) so writers never interleave, while readers
        # never wait.
        self._swap_lock = threading.Lock()
        self._install_locks: Dict[str, threading.Lock] = {}
        self._log_lock = threading.Lock()
        self._evict_lock = threading.Lock()
        # test-only injectable sync points (tests/test_serving_races.py):
        # named callables invoked at the store's sharp edges so races are
        # reproduced deterministically with events, not sleeps.  Empty dict
        # in production — one dict lookup per crossing, no locking.
        self._sync_points: Dict[str, Callable[[], None]] = {}
        # durable tier (DESIGN §10)
        self.autoflush = autoflush
        self.memory_budget_bytes = memory_budget_bytes
        self._dirty: set = set()
        self._last_access: Dict[str, int] = {}
        self._access_clock = itertools.count(1)
        self.durable = None
        # cluster tier (DESIGN §14): health tracking + the rebalance path
        # exist only when the durable tier is a ClusterDurableStore
        self.health = None
        # durable-only observability (DESIGN §15): per-run telemetry
        # history and the regression watchdog reading it
        self.telemetry = None
        self.watchdog = None
        if cluster is not None and root is None:
            raise ValueError("cluster=ClusterConfig(...) needs root= "
                             "(nodes are directories under the store root)")
        if root is not None:
            from .storage.durable import DurableStore
            if cluster is not None or os.path.exists(
                    os.path.join(root, "cluster.json")):
                if memory_budget_bytes is not None:
                    raise ValueError(
                        "a cluster store does not support "
                        "memory_budget_bytes: columns are reassembled "
                        "in RAM from per-node parts and cannot be "
                        "memmap-swapped to a single local segment")
                from ..cluster.control import ClusterHealth
                from ..cluster.node import ClusterDurableStore
                self.durable = ClusterDurableStore(
                    root, num_workers=num_workers,
                    max_retired_generations=max_retired_generations,
                    cluster=cluster)
                # health watches the LIVE membership (directory epoch),
                # not the bootstrap config; wired before _attach so the
                # very first reads feed the straggler detector
                self.health = ClusterHealth(self.durable.directory.nodes)
                self.durable.health = self.health
            else:
                self.durable = DurableStore(
                    root, num_workers=num_workers,
                    max_retired_generations=max_retired_generations)
            # an existing catalog is authoritative for the worker count —
            # segment layouts are (m, capacity) and cannot be re-bucketed
            # on open without a shuffle
            if self.durable.num_workers is not None:
                num_workers = self.durable.num_workers
            # durable telemetry history + regression watchdog (DESIGN
            # §15) live under the same root, so profiles and baselines
            # survive restarts with the data they describe
            from ..obs.telemetry import TelemetryStore
            from ..obs.watchdog import RegressionDetector
            self.telemetry = TelemetryStore(root)
            self.watchdog = RegressionDetector(self.telemetry)
            self._attach()
        self.m = num_workers

    @classmethod
    def open(cls, root: str, **kwargs) -> "PartitionStore":
        """Reattach to a durable store directory written by a previous
        process.  Worker count and dataset layouts come from the on-disk
        catalog; ``backend=`` etc. are this process's choices."""
        return cls(root=root, **kwargs)

    @property
    def is_durable(self) -> bool:
        return self.durable is not None

    @property
    def root(self) -> Optional[str]:
        return self.durable.root if self.durable is not None else None

    # -- cluster tier (DESIGN §14) -------------------------------------------
    @property
    def is_cluster(self) -> bool:
        return getattr(self.durable, "is_cluster", False)

    @property
    def directory(self):
        """Current :class:`~repro.cluster.directory.PartitionDirectory`
        epoch (None on a non-cluster store)."""
        return self.durable.directory if self.is_cluster else None

    @property
    def cluster_config(self):
        return self.durable.cluster if self.is_cluster else None

    @property
    def placement_epoch(self) -> int:
        """Placement generation the planner pins into PlanKeys: a
        rebalance bumps it, invalidating exactly the plans compiled
        against the old placement.  -1 on non-cluster stores (one value
        for every single-host store, so their keys are unaffected)."""
        return self.durable.directory.epoch if self.is_cluster else -1

    def plan_rebalance(self, **kwargs):
        """Plan (without applying) an incremental placement change —
        see :meth:`repro.cluster.rebalancer.Rebalancer.plan`."""
        from ..cluster.rebalancer import Rebalancer
        return Rebalancer(self).plan(**kwargs)

    def rebalance(self, plan=None, *, abort_after: Optional[int] = None,
                  on_abort=None, **kwargs):
        """Apply a placement change: ``plan`` from :meth:`plan_rebalance`,
        or plan-and-apply in one step (kwargs as for plan_rebalance).
        Returns a :class:`~repro.cluster.rebalancer.RebalanceResult`."""
        from ..cluster.rebalancer import Rebalancer
        r = Rebalancer(self)
        if plan is None:
            plan = r.plan(**kwargs)
        return r.apply(plan, abort_after=abort_after, on_abort=on_abort)

    def _attach(self) -> None:
        """Load every dataset's newest consistent generation as memmap
        views (zero-copy; nothing is paged in until first touch)."""
        for name, ds in self.durable.load_all().items():
            self.datasets[name] = ds

    def _log_write(self, entry: Dict[str, Any]) -> None:
        """Append a write_log row, folding overflow into the monotone
        aggregates so the log stays bounded under sustained traffic.
        Serialized: concurrent writers (the serving tier) must not lose
        counter increments to read-modify-write races."""
        with self._log_lock:
            self.write_log.append(entry)
            t = self.write_totals
            t["entries"] += 1
            t["rows"] += int(entry.get("rows", 0))
            t["bytes"] += int(entry.get("bytes", 0))
            t["latency_s"] += float(entry.get("latency", 0.0))
            t["padded_bytes"] += int(entry.get("padded_bytes", 0))
            t["valid_bytes"] += int(entry.get("valid_bytes", 0))
            t["max_skew"] = max(t["max_skew"],
                                float(entry.get("skew", 0.0)))
            while len(self.write_log) > self.write_log_cap:
                self.write_log.pop(0)
                t["evicted"] += 1

    def write_stats(self) -> Dict[str, float]:
        """Cumulative write counters (monotone across write_log eviction)."""
        with self._log_lock:
            return dict(self.write_totals)

    def register_metrics(self, registry) -> None:
        """Expose this store's cumulative stats through a
        :class:`~repro.obs.metrics.MetricsRegistry` (idempotent per
        registry).  The internal representations stay authoritative —
        ``write_totals`` folds evicted log rows, ``io_snapshot`` lives in
        the durable tier — so they are contributed as snapshot-time
        callbacks rather than migrated to registry counters."""
        marker = id(registry)
        regs = getattr(self, "_metric_registries", None)
        if regs is None:
            regs = self._metric_registries = set()
        if marker in regs:
            return
        regs.add(marker)
        registry.register_callback(self, PartitionStore._metric_samples)
        # the watchdog's coalesce-rate series reads serving counters out
        # of whichever registry the session exports through
        if self.watchdog is not None and self.watchdog.registry is None:
            self.watchdog.registry = registry

    def _metric_samples(self):
        for k, v in self.write_stats().items():
            yield f"store_write_{k}", {}, float(v)
        for k, v in self.io_snapshot().items():
            yield f"store_io_{k}", {}, float(v)
        yield "store_datasets", {}, float(len(self.datasets))
        yield "store_resident_bytes", {}, float(self.resident_bytes())
        if self.telemetry is not None:
            st = self.telemetry.stats()
            yield "telemetry_records", {}, float(st["records"])
            yield "telemetry_appends_total", {}, float(st["appends"])
            yield "telemetry_compactions_total", {}, float(st["compactions"])
        if self.watchdog is not None:
            yield ("watchdog_perf_regressions_total", {},
                   float(self.watchdog.raised_total))
            yield "watchdog_checks_total", {}, float(self.watchdog.checks)
        if self.is_cluster:
            for k, v in self.durable.cluster_snapshot().items():
                yield f"cluster_{k}", {}, float(v)
            d = self.durable.directory
            yield "cluster_epoch", {}, float(d.epoch)
            yield "cluster_directory_lookups_total", {}, float(d.lookups)
            yield "cluster_nodes", {}, float(len(d.nodes))
            if self.health is not None:
                yield ("cluster_heartbeat_misses_total", {},
                       float(self.health.heartbeat_misses))
                yield ("cluster_straggler_reissues_total", {},
                       float(self.health.straggler_reissues))
                yield ("cluster_nodes_alive", {},
                       float(len(self.health.alive_nodes())))

    # -- test-only race instrumentation (DESIGN §11) -------------------------
    def set_sync_point(self, point: str,
                       fn: Optional[Callable[[], None]]) -> None:
        """Install (or with ``None`` remove) a callable invoked when store
        internals cross ``point`` — e.g. ``install:pre_flip``,
        ``spill:column`` — so concurrency tests reproduce interleavings
        deterministically with :class:`threading.Event` barriers instead of
        sleeps.  Production stores never set these."""
        if fn is None:
            self._sync_points.pop(point, None)
        else:
            self._sync_points[point] = fn

    def _sync(self, point: str) -> None:
        fn = self._sync_points.get(point)
        if fn is not None:
            fn()

    def _name_lock(self, name: str) -> threading.Lock:
        with self._swap_lock:
            return self._install_locks.setdefault(name, threading.Lock())

    def _install(self, name: str, ds: StoredDataset,
                 persist: Optional[Callable[[StoredDataset], Any]] = None
                 ) -> StoredDataset:
        """Atomically make ``ds`` the current generation of ``name``.

        The flip is a single dict assignment under the (global) swap lock;
        readers that already hold the previous StoredDataset keep reading
        it unchanged (generations are immutable).  On a durable store with
        autoflush the generation is persisted (segments → manifest →
        CURRENT) *before* the in-memory flip, so the disk pointer never
        runs ahead of a generation that fully exists.  The fsync-bound
        persist runs under a per-NAME lock only (it serializes the
        generation sequence of this dataset), so a slow background
        repartition of one dataset never blocks writers of another.

        ``persist`` overrides the default durable publication for this
        install (always invoked, regardless of autoflush) — the
        Rebalancer passes one that republishes under a NEW placement
        epoch, keeping the flip semantics identical for MVCC readers."""
        with _span("store.install", "store", dataset=name) as sp:
            with self._name_lock(name):
                prev = self.datasets.get(name)
                if prev is not None:
                    ds.generation = prev.generation + 1
                if self.durable is not None:
                    if persist is not None:
                        persist(ds)
                        self._dirty.discard(name)
                    elif self.autoflush:
                        self.durable.persist(ds)
                        self._dirty.discard(name)
                    else:
                        self._dirty.add(name)
                self._sync("install:pre_flip")
                with self._swap_lock:
                    if prev is not None:
                        retired = self._retired.setdefault(name, [])
                        retired.append(prev)
                        if len(retired) > self.max_retired_generations:
                            del retired[:len(retired)
                                        - self.max_retired_generations]
                    self.datasets[name] = ds
                self._sync("install:post_flip")
            sp.set(generation=ds.generation)
        self._touch(name)
        self._maybe_evict()
        return ds

    def generation_of(self, name: str) -> int:
        return self.datasets[name].generation

    # -- durability (DESIGN §10) ---------------------------------------------
    def flush(self, name: Optional[str] = None) -> int:
        """Persist pending generations to the durable tier (all datasets,
        or just ``name``).  Returns the number of generations published.
        No-op (0) on a memory-only store."""
        if self.durable is None:
            return 0
        names = [name] if name is not None else sorted(list(self.datasets))
        published = 0
        for n in names:
            ds = self.datasets.get(n)
            if ds is None:
                continue
            if n in self._dirty or not self.durable.has_generation(
                    n, ds.generation):
                self.durable.persist(ds)
                self._dirty.discard(n)
                published += 1
        return published

    def io_snapshot(self) -> Dict[str, float]:
        """Copy of the durable tier's I/O counters (zeros when memory-only).
        The executor diffs this around a run to attribute storage I/O."""
        if self.durable is None:
            return {}
        return self.durable.io_snapshot()

    # -- eviction loop ---------------------------------------------------------
    def _touch(self, name: str) -> None:
        # itertools.count is a single C-level op — atomic under the GIL, so
        # concurrent readers never lose a tick (LRU stays consistent)
        self._last_access[name] = next(self._access_clock)

    def resident_bytes(self) -> int:
        """Bytes of column data currently held in RAM/device memory (spilled
        memmap views count as 0 — they are disk-backed).  Retired-but-
        retained generations count too: they hold real memory until their
        retention window closes."""
        with self._swap_lock:
            # snapshot under the writer lock: a concurrent install/retire
            # must not resize these containers mid-iteration
            live = list(self.datasets.values())
            retired = [d for lst in self._retired.values() for d in lst]
        total = 0
        for ds in live + retired:
            for v in list(ds.columns.values()):
                if not isinstance(v, np.memmap):
                    total += int(v.nbytes)
        return total

    def namespace_bytes(self, prefix: str = "") -> int:
        """Logical bytes of every current-generation dataset whose name
        starts with ``prefix`` — the serving tier's per-tenant accounting
        (tenants own disjoint name prefixes, DESIGN §11)."""
        with self._swap_lock:
            live = [d for n, d in self.datasets.items()
                    if n.startswith(prefix)]
        return int(sum(d.nbytes for d in live))

    def is_spilled(self, name: str) -> bool:
        return self.datasets[name].spilled

    def spill(self, name: str) -> bool:
        """Evict ``name``'s current generation to its segment files: columns
        become read-only memmap views (bit-identical by construction).
        Persists first if the generation isn't durable yet.  Returns False
        on a memory-only store, and on a cluster store (assembled columns
        span per-node parts — no single local segment to memmap)."""
        if self.durable is None or self.is_cluster:
            return False
        # the per-name lock serializes spill against a concurrent _install
        # of the same dataset (the generation sequence stays linear); other
        # datasets' writers are unaffected
        with _span("store.spill", "store", dataset=name) as sp:
            with self._name_lock(name):
                ds = self.datasets[name]
                if ds.spilled:
                    return True
                self.flush(name)
                man = self.durable.load_manifest(name, ds.generation)
                if man is None:          # validation failed — keep resident
                    sp.set(ok=False)
                    return False
                sp.set(generation=ds.generation)
                return self._swap_to_segments(ds, man)

    def _swap_to_segments(self, ds: StoredDataset, man) -> bool:
        """Replace ``ds``'s column containers with memmap views of their
        persisted segments (same bits, shared by every reader).

        Each column flips under the writer lock individually; a reader
        mid-``gather()`` may observe some columns in RAM and some as
        memmap views — bit-identical by construction, so the immutable-
        values contract holds (the ``spill:column`` sync point lets the
        race tests freeze exactly that mixed state)."""
        freed = sum(int(v.nbytes) for v in list(ds.columns.values())
                    if not isinstance(v, np.memmap))
        cols = self.durable.open_columns(ds.name, man)
        for k in list(ds.columns):
            self._sync("spill:column")
            with self._swap_lock:
                ds.columns[k] = cols[k]
        self._sync("spill:post_swap")
        self.durable.io_add(spills=1, spilled_bytes=freed)
        return True

    def _spill_retired(self) -> int:
        """Evict retired-but-retained generations first: they hold real
        memory, are never read on the hot path, and the durable tier
        retains the same generation window on disk."""
        spilled = 0
        for name, lst in self._retired.items():
            for old in lst:
                if old.spilled:
                    continue
                if not self.durable.has_generation(name, old.generation):
                    # segments + manifest only: CURRENT must never move
                    # backwards to a superseded generation
                    self.durable.persist(old, publish_current=False)
                man = self.durable.load_manifest(name, old.generation)
                if man is not None and self._swap_to_segments(old, man):
                    spilled += 1
        return spilled

    def prefetch(self, name: str) -> bool:
        """Promote a spilled dataset back to residency: in-RAM copies on a
        host store, device arrays (host→device prefetch) on a
        device-resident one.  Returns True when the dataset is resident."""
        with _span("store.prefetch", "store", dataset=name) as psp:
            with self._name_lock(name):
                ds = self.datasets[name]
                if not ds.spilled:
                    return True
                t0 = time.perf_counter()
                loaded = 0
                promoted: Columns = {}
                for k, v in list(ds.columns.items()):
                    arr = np.array(v)    # one sequential segment read
                    loaded += int(arr.nbytes)
                    if self._storage_prefetch:
                        promoted[k] = jax.numpy.asarray(arr) \
                            if dtype_roundtrips(arr.dtype) else arr
                    else:
                        promoted[k] = arr
                self._sync("prefetch:pre_swap")
                with self._swap_lock:
                    for k in list(ds.columns):
                        ds.columns[k] = promoted[k]
                if self.durable is not None:
                    self.durable.io_add(bytes_read=loaded,
                                        read_s=time.perf_counter() - t0,
                                        rehydrations=1,
                                        rehydrated_bytes=loaded)
                psp.set(bytes=loaded)
        self._touch(name)
        self._maybe_evict(exclude=name)
        return True

    def _maybe_evict(self, exclude: Optional[str] = None) -> int:
        """Enforce ``memory_budget_bytes``: spill coldest-first (LRU by
        last read/install) until resident bytes fit.  Requires the durable
        tier; a memory-only store never spills."""
        if self.memory_budget_bytes is None or self.durable is None:
            return 0
        # one evictor at a time: concurrent budget-crossers skip instead of
        # queueing up to spill the same victims (the holder restores the
        # invariant for everyone)
        if not self._evict_lock.acquire(blocking=False):
            return 0
        try:
            spilled = 0
            if self.resident_bytes() > self.memory_budget_bytes:
                spilled += self._spill_retired()
            while self.resident_bytes() > self.memory_budget_bytes:
                before = self.resident_bytes()
                with self._swap_lock:
                    candidates = [(n, d.spilled)
                                  for n, d in self.datasets.items()]
                victims = sorted(
                    (n for n, is_spilled in candidates
                     if not is_spilled and n != exclude),
                    key=lambda n: self._last_access.get(n, 0))
                if not victims:
                    break
                if not self.spill(victims[0]):
                    break
                spilled += 1
                if self.resident_bytes() >= before:
                    break                # no progress (e.g. 0-size columns)
            return spilled
        finally:
            self._evict_lock.release()

    # -- write path (storage-time partitioning) ------------------------------
    def write(self, name: str, data: Columns,
              partitioner: Optional[PartitionerCandidate] = None,
              seed: int = 0) -> StoredDataset:
        """Dispatch each row to a worker via ``g(d_i)`` and persist."""
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        if partitioner is None:
            partitioner = PartitionerCandidate(graph=None, strategy=ROUND_ROBIN)

        with _span("store.write", "store", dataset=name, rows=n,
                   strategy=partitioner.strategy):
            if self._device_resident:
                columns, counts, cmap = self._dispatch_device(
                    data, partitioner, n, seed)
            else:
                columns, counts, cmap = self._dispatch_host(
                    data, partitioner, n, seed)

        nbytes = int(sum(np.asarray(v).nbytes for v in data.values()))
        ds = StoredDataset(name=name, columns=columns,
                           counts=counts.astype(np.int64),
                           partitioner=partitioner, num_rows=n, nbytes=nbytes,
                           capacity_map=cmap)
        self._install(name, ds)
        self._log_write({
            "name": name, "rows": n, "bytes": nbytes,
            "strategy": partitioner.strategy,
            "latency": time.perf_counter() - t0,
            "skew": ds.skew(),
            "padded_bytes": ds.padded_bytes,
            "valid_bytes": ds.valid_bytes,
            "bucketed": cmap is not None,
            "generation": ds.generation,
        })
        return ds

    def _plan_cmap(self, counts) -> Optional[CapacityMap]:
        """Counts → bucketed CapacityMap when adaptive capacity is on and
        the re-layout saves enough padding; None ⇒ stay uniform."""
        if not self.adaptive_capacity:
            return None
        return plan_capacity_map(counts, threshold=self.capacity_threshold)

    # -- dispatch backends ---------------------------------------------------
    def _host_pids(self, data: Columns, partitioner: PartitionerCandidate,
                   n: int, seed: int) -> np.ndarray:
        pids = np.asarray(partitioner.partition_ids(data, self.m)) \
            if partitioner.strategy != RANDOM else \
            np.random.default_rng(seed).integers(0, self.m, size=n)
        return np.asarray(pids, np.int64)

    def _dispatch_host(self, data, partitioner, n, seed):
        """Host-side numpy dispatch: one counting-sort placement, then a
        single vectorized scatter per column (no per-worker Python loop)."""
        pids = self._host_pids(data, partitioner, n, seed)
        counts = np.bincount(pids, minlength=self.m)
        cmap = self._plan_cmap(counts)
        if cmap is not None:
            dest = _counting_sort_dest(pids, counts, 0,
                                       dest_offsets=cmap.offsets)
            total = cmap.total_slots
            columns: Columns = {}
            for k, v in data.items():
                v = np.asarray(v)
                buf = np.zeros((total,) + v.shape[1:], v.dtype)
                buf[dest] = v
                columns[k] = buf
            return columns, counts, cmap
        cap = int(counts.max()) if n else 1
        dest = _counting_sort_dest(pids, counts, cap)
        columns = {}
        for k, v in data.items():
            v = np.asarray(v)
            buf = np.zeros((self.m * cap,) + v.shape[1:], v.dtype)
            buf[dest] = v
            columns[k] = buf.reshape((self.m, cap) + v.shape[1:])
        return columns, counts, None

    def _dispatch_device(self, data, partitioner, n, seed):
        """Device dispatch (DESIGN §5): hash keys through the Pallas kernel,
        re-bucket with a jax scatter consuming its (pids, histogram) output.
        Keyless/range strategies — and partitioners that opt out of kernel
        dispatch (SaltedPartitioner's pid math is not the plain key hash) —
        keep their host pid computation but still scatter on device, so the
        stored columns are device-resident."""
        if (partitioner.strategy == HASH and partitioner.graph is not None
                and getattr(partitioner, "kernel_dispatchable", True)):
            keys = partitioner.key_fn()(data)
            pids, counts = shuffle_pids(keys, self.m,
                                        interpret=self.interpret)
        else:
            pids = self._host_pids(data, partitioner, n, seed)
            counts = np.bincount(pids, minlength=self.m).astype(np.int64)
        cmap = self._plan_cmap(counts)
        columns = device_scatter_padded(data, pids, counts,
                                        capacity_map=cmap,
                                        interpret=self.interpret)
        return columns, counts, cmap

    def write_layout(self, name: str, flat_columns: Columns,
                     counts: np.ndarray,
                     partitioner: Optional[PartitionerCandidate],
                     device_columns: Optional[Columns] = None
                     ) -> StoredDataset:
        """Persist an ALREADY-partitioned table (flat columns segmented per
        worker by ``counts``) without re-dispatching — used when a workload
        materializes an output whose layout was produced by its own
        partition nodes (e.g. iterative PageRank writing updated ranks).

        ``device_columns`` — device-resident flats from an upstream device
        shuffle (engine d2d chain); the device scatter consumes them in
        place of re-uploading the matching host columns."""
        counts = np.asarray(counts, np.int64)
        n = int(counts.sum())
        cmap = self._plan_cmap(counts)
        columns = self._materialize_layout(flat_columns, counts, cmap,
                                           device_columns=device_columns)
        nbytes = int(sum(np.asarray(v).nbytes for v in flat_columns.values()))
        ds = StoredDataset(name=name, columns=columns, counts=counts,
                           partitioner=partitioner, num_rows=n, nbytes=nbytes,
                           capacity_map=cmap)
        return self._install(name, ds)

    def _materialize_layout(self, flat_columns: Columns, counts: np.ndarray,
                            cmap: Optional[CapacityMap],
                            device_columns: Optional[Columns] = None
                            ) -> Columns:
        """Rows already segmented per worker (pids implied by ``counts``) →
        padded columns: uniform ``(m, cap, ...)`` when ``cmap`` is None,
        flat bucketed ``(total_slots, ...)`` otherwise.  Shared by
        write_layout and rebucket."""
        n = int(counts.sum())
        cap = int(counts.max()) if n else 1
        if self._device_resident:
            pids = np.repeat(np.arange(self.m, dtype=np.int32), counts)
            return device_scatter_padded(
                flat_columns, pids, counts,
                capacity=None if cmap is not None else cap,
                capacity_map=cmap, interpret=self.interpret,
                device_columns=device_columns)
        if cmap is not None:
            dest = _presorted_dest(counts, 0, dest_offsets=cmap.offsets)
            total = cmap.total_slots
            columns: Columns = {}
            for k, v in flat_columns.items():
                v = np.asarray(v)
                buf = np.zeros((total,) + v.shape[1:], v.dtype)
                buf[dest] = v
                columns[k] = buf
            return columns
        dest = _presorted_dest(counts, cap)
        columns = {}
        for k, v in flat_columns.items():
            v = np.asarray(v)
            buf = np.zeros((self.m * cap,) + v.shape[1:], v.dtype)
            buf[dest] = v
            columns[k] = buf.reshape((self.m, cap) + v.shape[1:])
        return columns

    def rebucket(self, name: str) -> Tuple[StoredDataset, int]:
        """Re-layout ``name``'s current generation under a fresh
        :class:`CapacityMap` planned from its live histogram — SAME
        partitioner, so consumer elisions survive and no rows cross the
        network (a local rewrite, not a shuffle).  Publishes the result as
        a new generation via the usual atomic flip; returns
        ``(new ds, 0 bytes moved)``.  A no-op (current ds, 0) when the
        planned layout equals the current one."""
        t0 = time.perf_counter()
        with _span("store.rebucket", "store", dataset=name) as sp:
            ds = self.read(name)
            counts = np.asarray(ds.counts, np.int64)
            cmap = plan_capacity_map(counts,
                                     threshold=self.capacity_threshold)
            if cmap == ds.capacity_map:
                sp.set(noop=True)
                return ds, 0
            flat = flatten_dataset(ds)
            new = StoredDataset(name=name,
                                columns=self._materialize_layout(
                                    flat, counts, cmap),
                                counts=counts, partitioner=ds.partitioner,
                                num_rows=ds.num_rows, nbytes=ds.nbytes,
                                capacity_map=cmap)
            self._install(name, new)
            sp.set(generation=new.generation, bucketed=cmap is not None)
        self._log_write({
            "name": name, "rows": new.num_rows, "bytes": new.nbytes,
            "strategy": ds.partitioner.strategy if ds.partitioner else None,
            "latency": time.perf_counter() - t0,
            "skew": new.skew(),
            "padded_bytes": new.padded_bytes,
            "valid_bytes": new.valid_bytes,
            "bucketed": cmap is not None,
            "path": "rebucket",
            "generation": new.generation,
        })
        return new, 0

    # -- read path -------------------------------------------------------------
    def read(self, name: str,
             generation: Optional[int] = None) -> StoredDataset:
        """Current generation of ``name``; pass ``generation`` to resolve a
        specific (possibly superseded, still-retained) one.

        On a device-resident durable store, reading a spilled dataset
        prefetches it host→device first (DESIGN §10); a host store reads
        straight through the memmap views (lazy page-in).

        Thread-safety (DESIGN §11): the current-generation hot path is
        LOCK-FREE — one dict lookup resolves an immutable StoredDataset,
        and a concurrent ``_install`` pointer flip is invisible to a reader
        that already resolved (generations are never mutated in place).
        Only the retired-generation fallback briefly takes the writer lock
        to snapshot the retention list."""
        ds = self.datasets[name]
        if generation is None or ds.generation == generation:
            self._touch(name)
            if self._storage_prefetch and ds.spilled:
                self.prefetch(name)
                return self.datasets.get(name, ds)
            return ds
        with self._swap_lock:
            retained = list(self._retired.get(name, ()))
        for old in reversed(retained):
            if old.generation == generation:
                return old
        if self.durable is not None:
            # a fresh process retains no in-memory retired generations, but
            # the durable tier keeps the same retention window on disk
            old = self.durable.load(name, generation)
            if old is not None:
                return old
        raise RetiredGenerationError(
            f"{name}@gen{generation} not found "
            f"(current gen {ds.generation}, retains last "
            f"{self.max_retired_generations})")

    def stored_partitioners(self) -> Dict[str, Optional[PartitionerCandidate]]:
        with self._swap_lock:
            return {n: d.partitioner for n, d in self.datasets.items()}

    # -- shuffle (the operation Lachesis exists to avoid) ------------------------
    def repartition(self, ds: StoredDataset,
                    partitioner: PartitionerCandidate,
                    name: Optional[str] = None,
                    mesh=None, swap: bool = False) -> Tuple[StoredDataset, int]:
        """Full shuffle.  Returns (new ds, bytes moved).

        Bytes moved = (m-1)/m of the dataset on average (every row whose new
        worker differs from its current one crosses the network).

        Device-to-device fast path (DESIGN §5): when both the store and the
        dataset are device-backed and the target is a keyed hash
        partitioner, the shuffle runs entirely on device — flatten by a
        device gather, hash with the compiled key projection, counting-sort
        scatter into the new layout — with no host ``gather()``/concatenate.
        Pass ``mesh`` to commit the result back onto the mesh
        (``sharding_bridge.device_put_dataset``) so repartitioned datasets
        stay mesh-placed.

        ``swap=True`` (DESIGN §8) rewrites the dataset *in place* as a new
        generation under its own name: the whole shuffle materializes off
        to the side, then one atomic pointer flip publishes it.  Concurrent
        readers holding the previous generation keep a consistent view."""
        t0 = time.perf_counter()
        moved = int(ds.nbytes * (self.m - 1) / self.m)
        name = name or (ds.name if swap else ds.name + "@reparted")
        with _span("store.repartition", "store", dataset=name,
                   bytes_moved=moved, swap=swap) as rsp:
            return self._repartition(ds, partitioner, name, mesh, swap,
                                     moved, t0, rsp)

    def _repartition(self, ds, partitioner, name, mesh, swap, moved, t0,
                     rsp) -> Tuple[StoredDataset, int]:
        if mesh is not None:
            from ..core.sharding_bridge import device_put_dataset
        if (self._device_resident and ds.backend == "device"
                and partitioner.strategy == HASH
                and partitioner.graph is not None
                and getattr(partitioner, "kernel_dispatchable", True)):
            rsp.set(path="d2d")
            columns, counts, cmap = device_repartition_dataset(
                ds, partitioner, self.m, interpret=self.interpret,
                plan_capacity=self._plan_cmap)
            new = StoredDataset(name=name, columns=columns, counts=counts,
                                partitioner=partitioner,
                                num_rows=int(counts.sum()),
                                nbytes=ds.nbytes, capacity_map=cmap)
            if mesh is not None:
                new = device_put_dataset(mesh, new)
            self._install(name, new)
            self._log_write({
                "name": name, "rows": new.num_rows, "bytes": new.nbytes,
                "strategy": partitioner.strategy,
                "latency": time.perf_counter() - t0,
                "skew": new.skew(), "path": "d2d",
                "padded_bytes": new.padded_bytes,
                "valid_bytes": new.valid_bytes,
                "bucketed": cmap is not None,
                "generation": new.generation,
            })
        else:
            rsp.set(path="host")
            flat = ds.gather()
            new = self.write(name, flat, partitioner)
            if mesh is not None:
                # same generation, mesh-placed columns — re-publish only if
                # no newer generation landed while we were placing (CAS)
                new = device_put_dataset(mesh, new)
                with self._swap_lock:
                    cur = self.datasets.get(name)
                    if cur is not None and cur.generation == new.generation:
                        self.datasets[name] = new
        return new, moved
