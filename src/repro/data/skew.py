"""Skew detection utilities: heavy-hitter sketch + Zipf key generator.

The sketch is a batch-vectorized Misra-Gries summary: ``k`` counters that
overestimate no key and underestimate any key by at most ``n / (k + 1)``.
The Observer runs it over each candidate's key column during the existing
per-candidate stats pass, so hot-key detection costs one ``np.unique``
per scanned dataset — no second pass over the data.

``zipf_keys`` is the canonical skewed key generator, promoted here from
``service/drivers.py`` so benchmarks and drivers share one definition.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HeavyHitterSketch", "zipf_keys"]


def zipf_keys(
    n: int,
    n_keys: int,
    alpha: float,
    rng: Optional[np.random.Generator] = None,
    seed: int = 0,
) -> np.ndarray:
    """Draw ``n`` Zipf(``alpha``)-distributed keys in ``[0, n_keys)``.

    Pass ``rng`` to draw from an existing generator (preserving its
    sequence for callers that interleave other draws); otherwise a fresh
    ``default_rng(seed)`` is used.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    return np.minimum(rng.zipf(float(alpha), int(n)) - 1, int(n_keys) - 1).astype(
        np.int64
    )


class HeavyHitterSketch:
    """Misra-Gries heavy-hitter summary with batch updates.

    Any key whose true frequency exceeds ``n / (k + 1)`` is guaranteed to
    be among the counters; reported counts underestimate by at most the
    total decrement, so ``max_fraction()`` is a lower bound on the hottest
    key's share — exactly the conservative direction for a split trigger.
    """

    def __init__(self, k: int = 8) -> None:
        if k < 1:
            raise ValueError(f"sketch size k must be >= 1, got {k}")
        self.k = int(k)
        self._counters: Dict[int, int] = {}
        self.n = 0

    def update(self, keys: Sequence[int]) -> "HeavyHitterSketch":
        arr = np.asarray(keys).reshape(-1)
        if arr.size == 0:
            return self
        vals, cnts = np.unique(arr, return_counts=True)
        self.n += int(arr.size)
        for v, c in zip(vals.tolist(), cnts.tolist()):
            self._counters[int(v)] = self._counters.get(int(v), 0) + int(c)
        # Misra-Gries decrement: shed mass until <= k counters survive.
        while len(self._counters) > self.k:
            dec = min(self._counters.values())
            self._counters = {
                key: cnt - dec for key, cnt in self._counters.items() if cnt > dec
            }
            if not self._counters:
                break
        return self

    def counters(self) -> Dict[int, int]:
        return dict(self._counters)

    def max_fraction(self) -> float:
        """Lower bound on the hottest key's share of all updates."""
        if self.n == 0 or not self._counters:
            return 0.0
        return max(self._counters.values()) / float(self.n)

    def heavy_hitters(self, fraction: float) -> List[Tuple[int, float]]:
        """Keys whose (lower-bound) share is at least ``fraction``."""
        if self.n == 0:
            return []
        out = [
            (key, cnt / float(self.n))
            for key, cnt in self._counters.items()
            if cnt / float(self.n) >= fraction
        ]
        out.sort(key=lambda kv: -kv[1])
        return out
