import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes
(16×16 single-pod, 2×16×16 multi-pod); every step function must
``.lower().compile()`` under its shardings; ``memory_analysis()`` proves it
fits, ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
    python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, list_archs, shapes_for
from ..pjit_utils import enable_spmd
from . import hlo_analysis, shardings, specs, steps
from .mesh import (HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16, make_production_mesh,
                   mesh_counts)


def _mesh_context(mesh):
    """jax >= 0.5 exposes jax.set_mesh; on 0.4.x the Mesh object itself is
    the context manager that installs the global mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _cost_dict(compiled):
    """compiled.cost_analysis() returns a dict (>=0.5) or [dict] (0.4.x)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               extra_cfg: Optional[Dict[str, Any]] = None,
               variant: Optional[Dict[str, Any]] = None):
    """Lower + compile one cell; returns (compiled, lowered, meta).

    ``extra_cfg`` overrides ArchConfig fields (remat_policy, accum_steps,
    mla_absorbed, ...); ``variant`` toggles spec-level knobs:
    cache_seq_shard (flash-decode cache layout), fsdp_params (decode
    weights sharded over DP too)."""
    import dataclasses
    from ..models import layers as _layers
    variant = variant or {}
    _layers.FLASH_DECODE_ENABLED = bool(variant.get("flash_decode", False))
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    enable_spmd(True)

    with _mesh_context(mesh):
        if shape.kind == "train":
            opt = steps.make_optimizer(cfg)
            inp = specs.input_specs(cfg, shape, opt)
            state_ps = shardings.train_state_pspecs(cfg, inp["state"], mesh)
            batch_ps = shardings.batch_pspecs(cfg, shape, mesh)
            fn = steps.make_train_step(cfg, opt)
            jitted = jax.jit(fn,
                             in_shardings=(_named(mesh, state_ps),
                                           _named(mesh, batch_ps)),
                             donate_argnums=(0,))
            lowered = jitted.lower(inp["state"], inp["batch"])
        elif shape.kind == "prefill":
            inp = specs.input_specs(cfg, shape)
            param_ps = shardings.param_pspecs(cfg, inp["params"], mesh)
            if cfg.param_count() >= shardings.FSDP_THRESHOLD:
                param_ps = shardings.shard_over_dp(param_ps, inp["params"], mesh)
            batch_ps = shardings.batch_pspecs(cfg, shape, mesh)
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(_named(mesh, param_ps),
                                               _named(mesh, batch_ps)))
            lowered = jitted.lower(inp["params"], inp["batch"])
        else:  # decode
            inp = specs.input_specs(cfg, shape)
            param_ps = shardings.param_pspecs(cfg, inp["params"], mesh)
            if (cfg.param_count() >= shardings.FSDP_THRESHOLD
                    or variant.get("fsdp_params")):
                param_ps = shardings.shard_over_dp(param_ps, inp["params"], mesh)
            cache_ps = shardings.cache_pspecs(
                cfg, inp["cache"], shape.global_batch, mesh,
                seq_shard_model=variant.get("cache_seq_shard", False))
            tok_dp = shardings.batch_axes_for(shape.global_batch, cfg, mesh)
            tok_spec = P(tok_dp if len(tok_dp) != 1 else tok_dp[0], None) \
                if tok_dp else P(None, None)
            fn = steps.make_decode_step(cfg)
            jitted = jax.jit(fn,
                             in_shardings=(_named(mesh, param_ps),
                                           _named(mesh, cache_ps),
                                           NamedSharding(mesh, tok_spec),
                                           NamedSharding(mesh, P())),
                             donate_argnums=(1,))
            lowered = jitted.lower(inp["params"], inp["cache"],
                                   inp["tokens"], inp["pos"])
        compiled = lowered.compile()
    return compiled, lowered, {"mesh": mesh, "cfg": cfg, "shape": shape}


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 extra_cfg: Optional[Dict[str, Any]] = None,
                 variant: Optional[Dict[str, Any]] = None,
                 verbose: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    compiled, lowered, meta = lower_cell(arch, shape_name,
                                         multi_pod=multi_pod,
                                         extra_cfg=extra_cfg,
                                         variant=variant)
    cfg, shape, mesh = meta["cfg"], meta["shape"], meta["mesh"]
    chips = mesh.devices.size

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    totals = hlo_analysis.analyze(hlo)      # loop-aware (scan bodies × trips)
    colls = totals.collectives
    coll_bytes = totals.collective_bytes

    flops = totals.flops                                   # per-device
    bytes_acc = totals.hbm_bytes                           # per-device

    # MODEL_FLOPS (global, useful): 6·N·tokens train; 2·N·tokens fwd-only
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_act * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_act * tokens

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / ICI_LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind,
        "extra_cfg": {k: str(v) for k, v in (extra_cfg or {}).items()},
        "variant": {k: str(v) for k, v in (variant or {}).items()},
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": colls,
        "xla_cost_analysis_once": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_global": model_flops,
        "useful_flop_ratio": (model_flops / (flops * chips)
                              if flops else 0.0),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "compile_s": round(time.time() - t0, 1),
    }
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[{rec['mesh']}] {arch} × {shape_name}: "
              f"args={ma['argument_bytes']/2**30:.2f}GiB "
              f"temp={ma['temp_bytes']/2**30:.2f}GiB "
              f"flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"coll/dev={coll_bytes:.3e}  bottleneck={rec['bottleneck']} "
              f"({rec['compile_s']}s)", flush=True)
    return rec


def run_all(multi_pod: bool, out_path: Optional[str] = None,
            archs=None) -> Dict[str, Any]:
    results, failures = [], []
    for arch in (archs or list_archs()):
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            try:
                results.append(analyze_cell(arch, shape.name,
                                            multi_pod=multi_pod))
            except Exception as e:               # a failure here is a bug
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape.name,
                                 "error": repr(e)})
    payload = {"multi_pod": multi_pod, "results": results,
               "failures": failures}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {out_path}: {len(results)} ok, {len(failures)} failed")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k",
                    choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        run_all(args.multi_pod, args.out, archs=[args.arch] if args.arch
                else None)
        return
    rec = analyze_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    compiled, lowered, _ = lower_cell(args.arch, args.shape,
                                      multi_pod=args.multi_pod)
    print(compiled.memory_analysis())
    print({k: v for k, v in _cost_dict(compiled).items()
           if k in ("flops", "bytes accessed")})
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
