"""Train / serve step factories (pure functions of (state, batch))."""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer as T
from ..optimizer.adamw import AdamW, AdamWState, global_norm
from ..optimizer.schedule import warmup_cosine


def make_optimizer(cfg: ArchConfig, peak_lr: float = 3e-4,
                   total_steps: int = 10_000) -> AdamW:
    return AdamW(lr=warmup_cosine(peak_lr, min(500, total_steps // 10 + 1),
                                  total_steps),
                 b1=0.9, b2=0.95, weight_decay=0.1, grad_clip_norm=1.0,
                 state_dtype=jnp.bfloat16 if cfg.opt_state_bf16 else None)


def init_train_state(cfg: ArchConfig, key, optimizer: AdamW,
                     compression: Optional[str] = None) -> Dict[str, Any]:
    params = T.init_params(cfg, key)
    state = {"params": params, "opt": optimizer.init(params)}
    if compression:
        from ..optimizer.compression import init_error_feedback
        state["ef"] = init_error_feedback(params)
    return state


def make_train_step(cfg: ArchConfig, optimizer: AdamW,
                    compression: Optional[str] = None,
                    topk_frac: float = 0.05) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``cfg.accum_steps > 1`` microbatches the global batch through a
    ``lax.scan`` gradient accumulation (one live microbatch of activations).

    ``compression`` ∈ {None, "int8", "topk"}: compress gradients before the
    DP reduction with error feedback (state carries the residual).  On real
    hardware the psum operates on the compressed payload; here the
    compress→decompress pair is applied in-program and the wire-byte count
    is returned in metrics."""

    def loss_of(params, batch):
        return T.loss_fn(cfg, params, batch)

    def train_step(state, batch):
        params = state["params"]
        A = cfg.accum_steps
        if A == 1:
            (loss, met), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _m), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                return (jax.tree.map(lambda a, b: a + b, g_acc, g),
                        l_acc + l), None
            mb0 = jax.tree.map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)
            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(micro,
                                            (zeros, jnp.zeros((), jnp.float32)),
                                            mb0)
            grads = jax.tree.map(lambda g: g / A, grads)
            loss = loss / A
            met = {"ce": loss, "moe_aux": jnp.zeros((), jnp.float32)}
        gnorm = global_norm(grads)
        new_state = {}
        if compression is not None:
            from ..optimizer import compression as C
            ef = state["ef"]
            if compression == "int8":
                grads, ef, wire = C.compress_int8(grads, ef)
            elif compression == "topk":
                grads, ef, wire = C.compress_topk(grads, ef, frac=topk_frac)
            else:
                raise ValueError(compression)
            new_state["ef"] = ef
            met = dict(met, wire_bytes=jnp.asarray(wire, jnp.float32))
        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        metrics = {"loss": loss, "grad_norm": gnorm, **met}
        new_state.update({"params": new_params, "opt": new_opt})
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch["tokens"],
                         frames=batch.get("frames"))
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos)
    return decode_step
