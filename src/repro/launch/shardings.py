"""Sharding rules: param/batch/cache PartitionSpecs for every arch × mesh.

This is the *default persistent partitioning* of the model state — the
baseline the Lachesis sharding advisor (core/sharding_advisor.py) starts
from.  Rules are path-based over the params pytree:

  column-parallel (out-dim over "model"): wq wk wv wq_a wq_b wkv_b in_proj
      in_x in_gate w_r w_i w_in w_gate, ssd/rglru conv channels
  row-parallel   (in-dim over "model"):  wo out out_proj w_out
  expert-parallel: MoE (E, ·, ·) tensors sharded on E over "model"
  vocab-parallel: embedding / unembedding tables on dim 0
  replicated: norms, routers, tiny vectors (Λ, A_log, D, dt_bias)

Small models (< 1B params) use pure data parallelism: params replicated,
batch sharded over every mesh axis that divides it — the layout a sharding
advisor picks when TP collectives would dominate.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec

COL_PARENTS = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_b", "in_proj",
               "in_x", "in_gate", "w_r", "w_i", "w_in", "w_gate"}
ROW_PARENTS = {"wo", "out", "out_proj", "w_out"}
REPLICATED_PARENTS = {"wkv_a", "router"}   # latent proj small → cache replicated
TINY_LEAVES = {"lam", "A_log", "D", "dt_bias", "scale", "bias", "conv_b"}


def _axis_sizes(mesh) -> Dict[str, int]:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape)


def small_model(cfg: ArchConfig, threshold: float = 1e9) -> bool:
    return cfg.param_count() < threshold


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _base_param_rule(parts, shape, model: int) -> P:
    """Rule for an UNstacked param leaf."""
    leaf = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    nd = len(shape)

    if leaf in TINY_LEAVES or parent.startswith("ln") or \
            parent in ("final_norm", "norm", "q_norm", "k_norm", "kv_norm"):
        return P(*([None] * nd))
    if leaf == "table":                                   # embed / unembed
        return P("model" if _div(shape[0], model) else None, None)
    if leaf == "pos_embed" or parts[-1] == "pos_embed":
        return P(None, None)
    if parent in REPLICATED_PARENTS:
        return P(*([None] * nd))
    if leaf == "conv_w" and nd == 2:                      # (W, C) depthwise
        return P(None, "model" if _div(shape[1], model) else None)
    if nd == 3 and leaf in ("w_in", "w_gate", "w_out"):   # MoE experts (E,·,·)
        return P("model" if _div(shape[0], model) else None, None, None)
    if leaf == "w" and parent in COL_PARENTS:
        return P(None, "model" if _div(shape[1], model) else None)
    if leaf == "w" and parent in ROW_PARENTS:
        return P("model" if _div(shape[0], model) else None, None)
    if leaf == "b":
        if parent in COL_PARENTS:
            return P("model" if _div(shape[0], model) else None)
        return P(None)
    return P(*([None] * nd))                              # default: replicate


def param_pspecs(cfg: ArchConfig, params_struct: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_struct``."""
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    dp_only = small_model(cfg)

    def rule(path, leaf):
        shape = leaf.shape
        if dp_only:
            # pure DP: replicate everything (advisor-selected for <1B)
            return P(*([None] * len(shape)))
        parts = _path_str(path).split("/")
        stacked = parts[0] in ("blocks", "encoder") and "blocks" in parts[:2]
        base_parts = [p for p in parts if not (p.startswith("s")
                                               and p[1:].isdigit())]
        if stacked:
            base = _base_param_rule(base_parts, shape[1:], model)
            return P(None, *base)
        return _base_param_rule(base_parts, shape, model)

    return jax.tree_util.tree_map_with_path(rule, params_struct)


def batch_axes_for(B: int, cfg: ArchConfig, mesh: Mesh) -> Tuple[str, ...]:
    """Largest mesh-axis prefix whose product divides B.  Small models also
    spread batch over the model axis (pure DP over the whole pod)."""
    sizes = _axis_sizes(mesh)
    names = [a for a in mesh.axis_names if a != "model"]
    if small_model(cfg):
        names = names + ["model"]
    while names:
        prod = math.prod(sizes[a] for a in names)
        if _div(B, prod):
            return tuple(names)
        names.pop()                                       # drop last axis
    return ()


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                 batch_override: Optional[int] = None) -> Dict[str, P]:
    B = batch_override or shape.global_batch
    dp = batch_axes_for(B, cfg, mesh)
    dp_spec = dp if len(dp) != 1 else dp[0]
    specs = {"tokens": P(dp_spec, None), "labels": P(dp_spec, None)}
    if cfg.encoder is not None:
        specs["frames"] = P(dp_spec, None, None)
    return specs


def _cache_leaf_rule(parts, shape, dp: Tuple[str, ...], dp_size: int,
                     model: int) -> P:
    leaf = parts[-1]
    nd = len(shape)
    dp_spec: Any = (dp if len(dp) != 1 else dp[0]) if dp else None

    # strip stacked leading dims (blocks G axis / cross layer axis)
    lead = 1 if parts[0] in ("blocks", "cross") else 0
    core = shape[lead:]
    pre = [None] * lead

    def b_or_l(B, Lc):
        """Shard batch over dp when it divides; else shard the cache's
        sequence axis (ring/sequence-parallel KV for batch-1 long context)."""
        if dp and _div(B, dp_size):
            return dp_spec, None
        if dp and Lc is not None and _div(Lc, dp_size):
            return None, dp_spec
        return None, None

    if leaf in ("k", "v"):                                # (B, L, KV, hd)
        B, Lc, KV, hd = core
        b_ax, l_ax = b_or_l(B, Lc)
        if _div(KV, model):
            return P(*pre, b_ax, l_ax, "model", None)
        if _div(hd, model):
            return P(*pre, b_ax, l_ax, None, "model")
        return P(*pre, b_ax, l_ax, None, None)
    if leaf == "ckv":                                     # (B, L, R)
        B, Lc, R = core
        b_ax, l_ax = b_or_l(B, Lc)
        return P(*pre, b_ax, l_ax, "model" if _div(R, model) else None)
    if leaf == "krope":
        B, Lc, _ = core
        b_ax, l_ax = b_or_l(B, Lc)
        return P(*pre, b_ax, l_ax, None)
    if leaf == "h" and len(core) == 4:                    # ssd (B,H,P,N)
        B, H, Pd, N = core
        b_ax, _ = b_or_l(B, None)
        return P(*pre, b_ax, "model" if _div(H, model) else None, None, None)
    if leaf == "h" and len(core) == 2:                    # rglru (B,W)
        B, W = core
        b_ax, _ = b_or_l(B, None)
        return P(*pre, b_ax, "model" if _div(W, model) else None)
    if leaf == "conv":                                    # (B, W-1, C)
        B, _, C = core
        b_ax, _ = b_or_l(B, None)
        return P(*pre, b_ax, None, "model" if _div(C, model) else None)
    return P(*([None] * nd))


def cache_pspecs(cfg: ArchConfig, cache_struct: Any, B: int,
                 mesh: Mesh, seq_shard_model: bool = False) -> Any:
    """``seq_shard_model``: additionally shard the cache SEQUENCE axis over
    "model" (flash-decode style — each model rank attends over L/mp keys and
    the softmax combines via psum).  §Perf decode hillclimb knob."""
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    if small_model(cfg):
        dp = dp + ("model",)
    dp_size = math.prod(sizes[a] for a in dp) if dp else 1
    model_eff = 0 if small_model(cfg) else model   # 0 ⇒ never model-shard

    def rule(path, leaf):
        parts = _path_str(path).split("/")
        parts = [p for p in parts if not (p.startswith("s") and p[1:].isdigit())]
        spec = _cache_leaf_rule(parts, leaf.shape, dp, dp_size, model_eff)
        if seq_shard_model and parts[-1] in ("k", "v", "ckv", "krope"):
            lead = 1 if parts[0] in ("blocks", "cross") else 0
            seq_dim = lead + 1
            Ld = leaf.shape[seq_dim]
            if Ld % max(model, 1) == 0 and model > 1:
                # move the model axis from heads/hd onto the sequence dim
                entries = [None if e == "model" else e for e in list(spec)]
                entries[seq_dim] = "model"
                spec = P(*entries)
        return spec

    return jax.tree_util.tree_map_with_path(rule, cache_struct)


def shard_over_dp(param_specs: Any, params_struct: Any, mesh: Mesh,
                  skip_stacked_dim: bool = True) -> Any:
    """Additionally shard each tensor over the DP axes along the first
    unsharded, divisible dimension.  Used for (a) ZeRO-1 optimizer moments
    and (b) FSDP parameter sharding of ≥50B models.  The scanned layer-stack
    axis (dim 0 under blocks/) is skipped — sharding it would turn every
    scan iteration into a cross-DP dynamic-slice."""
    sizes = _axis_sizes(mesh)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_size = math.prod(sizes[a] for a in dp) if dp else 1
    dp_spec: Any = dp if len(dp) != 1 else (dp[0] if dp else None)

    def rule(path, spec, leaf):
        if dp_size <= 1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = {a for e in entries if e is not None
                for a in (e if isinstance(e, tuple) else (e,))}
        if used & set(dp):
            return P(*entries)          # already dp-sharded somewhere
        parts = _path_str(path).split("/")
        stacked = parts[0] in ("blocks", "encoder") and skip_stacked_dim
        start = 1 if stacked else 0
        for i in range(start, len(entries)):
            if entries[i] is None and leaf.shape[i] % dp_size == 0:
                entries[i] = dp_spec
                break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, param_specs, params_struct)


FSDP_THRESHOLD = 50e9     # params ≥ 50B: shard params over DP axes too


def train_state_pspecs(cfg: ArchConfig, state_struct: Any, mesh: Mesh,
                       zero1: bool = True,
                       fsdp: Optional[bool] = None) -> Any:
    """Specs for {"params", "opt": AdamWState(step, m, v)}."""
    pspec = param_pspecs(cfg, state_struct["params"], mesh)
    fsdp = (cfg.param_count() >= FSDP_THRESHOLD) if fsdp is None else fsdp
    if fsdp:
        pspec = shard_over_dp(pspec, state_struct["params"], mesh)
    mspec = pspec
    if zero1 and not small_model(cfg):
        mspec = shard_over_dp(pspec, state_struct["params"], mesh)
    opt = state_struct["opt"]
    return {"params": pspec,
            "opt": type(opt)(step=P(), m=mspec, v=mspec)}


def to_named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
