"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import and only then calls
``make_production_mesh``.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    """Mesh axes the batch is sharded over."""
    return ("pod", "data") if multi_pod else ("data",)


def mesh_counts(mesh) -> Tuple[int, int]:
    """(dp_size, model_size) of a production mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return dp, model


# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_LINK_BW = 50e9             # bytes/s per link
