"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns everything a step function is lowered
with: train → (state, batch); prefill → (params, batch); decode →
(params, cache, tokens, pos).  The same structs feed the sharding rules.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..models import transformer as T
from ..optimizer.adamw import AdamW

SDS = jax.ShapeDtypeStruct


def params_struct(cfg: ArchConfig) -> Any:
    key = SDS((2,), jnp.uint32)
    return jax.eval_shape(partial(T.init_params, cfg), key)


def state_struct(cfg: ArchConfig, optimizer: AdamW) -> Any:
    ps = params_struct(cfg)
    opt = jax.eval_shape(optimizer.init, ps)
    return {"params": ps, "opt": opt}


def batch_struct(cfg: ArchConfig, shape: ShapeSpec,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None) -> Dict[str, SDS]:
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    out = {"tokens": SDS((B, S), jnp.int32),
           "labels": SDS((B, S), jnp.int32)}
    if cfg.encoder is not None:
        out["frames"] = SDS((B, cfg.encoder.num_frames, cfg.d_model),
                            jnp.dtype(cfg.param_dtype))
    return out


def cache_struct(cfg: ArchConfig, B: int, Lc: int) -> Any:
    # B/Lc stay static Python ints (shape-building); eval_shape only
    # abstracts away the zeros allocation
    return jax.eval_shape(lambda: T.init_cache(cfg, B, Lc))


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                optimizer: Optional[AdamW] = None) -> Dict[str, Any]:
    """All lowering inputs for one (arch × shape) cell."""
    if shape.kind == "train":
        assert optimizer is not None
        return {"state": state_struct(cfg, optimizer),
                "batch": batch_struct(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_struct(cfg),
                "batch": batch_struct(cfg, shape)}
    if shape.kind == "decode":
        B = shape.global_batch
        return {"params": params_struct(cfg),
                "cache": cache_struct(cfg, B, shape.seq_len),
                "tokens": SDS((B, 1), jnp.int32),
                "pos": SDS((), jnp.int32)}
    raise ValueError(shape.kind)
