"""Loop-aware static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over G layer groups under-counts the body's FLOPs, bytes and
collectives by ~G×.  This analyzer parses the HLO text into computations,
extracts ``while`` trip counts from their condition computations, and
recursively totals:

* ``flops``           — 2·M·N·K for every ``dot`` (incl. dots inside fusion
                        computations, attributed to the call site)
* ``hbm_bytes``       — Σ (operand + result bytes) of top-level ops
                        (fusion boundaries ≈ HBM traffic; fusion-internal
                        ops excluded)
* ``collective bytes``— Σ result bytes per collective kind

all scaled by loop trip counts.  Everything is **per device** (the HLO
module is the SPMD-partitioned per-device program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_instr(line: str) -> Optional[Tuple[str, str, str, str]]:
    """Parse '  %name = TYPE opcode(rest...' → (name, type, opcode, rest).
    Handles tuple types with balanced parens."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, s = s[:i + 1], s[i + 1:]
    else:
        mt = re.match(r"\s*\w+\[[^\]]*\](?:\{[^}]*\})?", s)
        if not mt:
            return None
        type_str, s = mt.group(0), s[mt.end():]
    mo = re.match(r"\s*([\w\-]+)\((.*)$", s)
    if not mo:
        return None
    return name, type_str.strip(), mo.group(1), mo.group(2)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    out = []
    for _dt, dims in _ARRAY_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # operand list + attributes (single line)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> type


def _logical_lines(hlo: str) -> List[str]:
    """HLO text wraps long instructions across physical lines; join them.
    A new logical line starts at '%name', 'ROOT', 'ENTRY', '}' or module
    header; anything else continues the previous line."""
    out: List[str] = []
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s:
            continue
        if (s.startswith("%") or s.startswith("ROOT ")
                or s.startswith("ENTRY") or s == "}"
                or s.startswith("HloModule")):
            if cur is not None:
                out.append(cur)
            cur = raw
        elif cur is not None:
            cur = cur + " " + s
        else:
            cur = raw
    if cur is not None:
        out.append(cur)
    return [_COMMENT_RE.sub("", l) for l in out]


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in _logical_lines(hlo):
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line) and " = " not in line.split("->")[0]:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        parsed = _parse_instr(line)
        if parsed:
            ins = Instr(*parsed)
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
    return comps, entry


_CALL_TARGET_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition ≈ trip count for
    jax-lowered scans (compare(iv, constant(G)))."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            for c in _CONST_RE.finditer(ins.type_str + " constant(" +
                                        ins.rest):
                best = max(best, int(c.group(1)))
        for c in _CONST_RE.finditer(ins.rest):
            best = max(best, int(c.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 × (product of result dims) × (product of contracted dims)."""
    res_dims = _shape_dims(ins.type_str)
    if not res_dims:
        return 0.0
    out_elems = 1
    for d in res_dims[0]:
        out_elems *= d
    # contracted dims from lhs operand type + lhs_contracting_dims
    ops = _OPERAND_RE.findall(ins.rest.split("),")[0] + ")")
    lhs_type = comp.symbols.get(ops[0]) if ops else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contracted = 1
    if lhs_type and m:
        lhs_dims = _shape_dims(lhs_type)
        if lhs_dims:
            for idx in m.group(1).split(","):
                if idx:
                    i = int(idx)
                    if i < len(lhs_dims[0]):
                        contracted *= lhs_dims[0][i]
    return 2.0 * out_elems * contracted


@dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collectives.items():
            rec = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            rec["count"] += v["count"] * mult
            rec["bytes"] += v["bytes"] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())


# opcodes whose operand/result traffic hits HBM (fusion boundaries)
_MEM_OPS = {"fusion", "dot", "convolution", "copy", "dynamic-update-slice",
            "dynamic-slice", "transpose", "reshape", "broadcast", "reduce",
            "scatter", "gather", "select-and-scatter", "sort", "concatenate",
            "slice", "pad", "reverse", "add", "multiply", "subtract",
            "divide", "tanh", "exponential", "convert", "iota",
            "rng-bit-generator"} | set(COLLECTIVE_KINDS) \
    | {k + "-start" for k in COLLECTIVE_KINDS} | {"all-reduce-start"}


def _analyze_comp(name: str, comps: Dict[str, Computation],
                  cache: Dict[str, Totals]) -> Totals:
    if name in cache:
        return cache[name]
    cache[name] = Totals()          # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return cache[name]
    t = Totals()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "dot":
            t.flops += _dot_flops(ins, comp)
            t.hbm_bytes += _op_bytes(ins, comp)
        elif op == "fusion":
            # attribute fused dots' flops to the call site
            tgt = _CALL_TARGET_RE.search(ins.rest)
            if tgt:
                sub = comps.get(tgt.group(1))
                if sub:
                    for sins in sub.instrs:
                        if sins.opcode == "dot":
                            t.flops += _dot_flops(sins, sub)
            t.hbm_bytes += _op_bytes(ins, comp)
        elif op == "while":
            tgt = dict(re.findall(r"(body|condition)=\{?%?([\w.\-]+)",
                                  ins.rest))
            trips = 1
            if "condition" in tgt and tgt["condition"] in comps:
                trips = _trip_count(comps[tgt["condition"]])
            if "body" in tgt:
                t.add(_analyze_comp(tgt["body"], comps, cache), trips)
            t.hbm_bytes += _shape_bytes(ins.type_str)
        elif op in ("call", "custom-call", "conditional", "async-start"):
            for tgt in _CALL_TARGET_RE.finditer(ins.rest):
                t.add(_analyze_comp(tgt.group(1), comps, cache), 1.0)
            t.hbm_bytes += _op_bytes(ins, comp)
        else:
            base = op.replace("-start", "")
            if base in COLLECTIVE_KINDS:
                rec = t.collectives.setdefault(base,
                                               {"count": 0.0, "bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += _shape_bytes(ins.type_str)
                t.hbm_bytes += _op_bytes(ins, comp)
            elif op in _MEM_OPS:
                t.hbm_bytes += _op_bytes(ins, comp)
    cache[name] = t
    return t


def _op_bytes(ins: Instr, comp: Computation) -> float:
    """HBM traffic of one op = result bytes + operand bytes actually read.

    dynamic-slice reads only the slice (= result), and dynamic-update-slice
    writes only the updated region (= update operand) with the rest aliased
    in place — counting their full operands would charge a whole KV cache
    per single-token write (measured 5–20× inflation on decode cells)."""
    result = float(_shape_bytes(ins.type_str))
    if ins.opcode == "dynamic-slice":
        return 2.0 * result                     # read slice + write result
    oplist = ins.rest.split(")")[0]
    names = _OPERAND_RE.findall(oplist)
    if ins.opcode == "dynamic-update-slice":
        # operands: (target, update, indices...) — read+write the update
        ts = comp.symbols.get(names[1]) if len(names) > 1 else None
        return 2.0 * float(_shape_bytes(ts)) if ts else result
    total = result
    for name in names:
        ts = comp.symbols.get(name)
        if ts:
            total += _shape_bytes(ts)
    return total


def analyze(hlo: str) -> Totals:
    comps, entry = parse_module(hlo)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    # fusion computations are only counted via their call sites; entry drives
    return _analyze_comp(entry, comps, {})


# -- thin wrappers kept for callers -------------------------------------------

def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return analyze(hlo_text).collectives


def total_collective_bytes(hlo_text: str) -> float:
    return analyze(hlo_text).collective_bytes


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))
