"""End-to-end training driver: data pipeline → train loop → checkpoints,
with fault-tolerant restart, straggler-tolerant input, and history logging
so Lachesis can advise future runs.

CPU-scale usage (examples/train_lm.py):
    python -m repro.launch.train --arch mamba2-370m --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a pod, the same loop runs under the dry-run's shardings (see dryrun.py);
this driver is the single-host reference implementation.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                     save_checkpoint)
from ..configs import get_config
from ..configs.reduced import reduced as make_reduced
from ..data.pipeline import DataConfig, TokenSource
from ..runtime.fault_tolerance import Coordinator, WorkerFailure
from ..runtime.straggler import StragglerMitigator
from . import steps as steps_lib


@dataclasses.dataclass
class TrainRun:
    cfg: Any
    total_steps: int
    global_batch: int
    seq_len: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    peak_lr: float = 3e-4
    seed: int = 0
    log_every: int = 10
    fail_at_step: Optional[int] = None     # fault-injection for tests


def train(run: TrainRun) -> Dict[str, Any]:
    cfg = run.cfg
    opt = steps_lib.make_optimizer(cfg, peak_lr=run.peak_lr,
                                   total_steps=run.total_steps)
    train_step = jax.jit(steps_lib.make_train_step(cfg, opt),
                         donate_argnums=(0,))
    source = TokenSource(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=run.seq_len,
                                    global_batch=run.global_batch,
                                    num_hosts=1, seed=run.seed))
    coord = Coordinator(num_workers=1)
    straggler = StragglerMitigator()

    # init or restore
    state = steps_lib.init_train_state(cfg, jax.random.PRNGKey(run.seed), opt)
    start = 0
    if run.ckpt_dir and latest_step(run.ckpt_dir) is not None:
        state, start, extra = restore_checkpoint(run.ckpt_dir, state)
        print(f"[train] restored step {start}")

    losses = []
    t0 = time.time()
    step = start
    while step < run.total_steps:
        if run.fail_at_step is not None and step == run.fail_at_step:
            run.fail_at_step = None            # fail once
            raise WorkerFailure(f"injected failure at step {step}")
        batch_np = straggler.fetch_shard(
            lambda s, h: source.batch_at(s, h), step, host=0, backup_host=0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.encoder is not None:
            batch["frames"] = jnp.zeros(
                (run.global_batch, cfg.encoder.num_frames, cfg.d_model),
                jnp.dtype(cfg.param_dtype))
        state, metrics = train_step(state, batch)
        coord.heartbeat(0, step)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % run.log_every == 0:
            rate = (step - start + 1) / (time.time() - t0)
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({rate:.2f} steps/s)", flush=True)
        step += 1
        if run.ckpt_dir and step % run.ckpt_every == 0:
            save_checkpoint(run.ckpt_dir, step, state,
                            extra={"data_step": step})
    if run.ckpt_dir:
        save_checkpoint(run.ckpt_dir, step, state,
                        extra={"data_step": step})
    return {"state": state, "losses": losses, "final_step": step}


def train_with_restarts(run: TrainRun, max_attempts: int = 4):
    """Crash-recovery wrapper: restart from the latest checkpoint on
    (injected or real) worker failure."""
    for attempt in range(max_attempts):
        try:
            return train(run)
        except WorkerFailure as e:
            print(f"[train] {e} — restarting from checkpoint "
                  f"(attempt {attempt + 1})")
    raise RuntimeError("too many restarts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced sibling config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = dataclasses.replace(cfg, accum_steps=args.accum)
    out = train_with_restarts(TrainRun(
        cfg=cfg, total_steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir, peak_lr=args.lr))
    print(f"[train] done: loss {out['losses'][0]:.4f} → "
          f"{out['losses'][-1]:.4f} over {out['final_step']} steps")


if __name__ == "__main__":
    main()
