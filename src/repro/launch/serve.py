"""Batched LM serving driver: prefill a batch of prompts, decode N tokens.

This is the *model inference* driver for the LM workload suite.  The
serving tier for the analytics engine itself — concurrent workloads over
one shared PartitionStore, with admission control, request coalescing and
per-tenant namespaces — lives in ``repro.service.serving``
(``Session.serve()``, DESIGN §11), not here.

CPU-scale usage (examples/serve_batch.py):
    python -m repro.launch.serve --arch internlm2-1.8b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.reduced import reduced as make_reduced
from ..models import transformer as T


def serve_batch(cfg, params, prompts: np.ndarray, gen_tokens: int,
                frames=None, greedy: bool = True, seed: int = 0):
    """prompts: (B, S) int32 → (B, gen_tokens) generated ids + stats."""
    B, S = prompts.shape
    cache_len = S + gen_tokens
    prefill = jax.jit(lambda p, t, f: T.prefill(cfg, p, t, frames=f,
                                                cache_len=cache_len))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill(params, jnp.asarray(prompts), frames)
    prefill_s = time.time() - t0

    key = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(gen_tokens):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits)[:, None].astype(jnp.int32)
    decode_s = time.time() - t0
    gen = np.stack(out, axis=1)
    return gen, {"prefill_s": prefill_s, "decode_s": decode_s,
                 "tokens_per_s": B * gen_tokens / max(decode_s, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    prompts = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len), 0,
                           cfg.vocab_size), np.int32)
    frames = None
    if cfg.encoder is not None:
        frames = jnp.zeros((args.batch, cfg.encoder.num_frames, cfg.d_model),
                           jnp.dtype(cfg.param_dtype))
    gen, stats = serve_batch(cfg, params, prompts, args.gen, frames=frames)
    print(f"[serve] generated {gen.shape} prefill={stats['prefill_s']:.2f}s "
          f"decode={stats['decode_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
