"""MetricsRegistry — the one place runtime counters live (DESIGN §13).

Before this layer, every subsystem kept its own ad-hoc stats dict
(planner ``cache_stats()``, store ``write_stats()``/``io_stats``, serving
``stats()``, the device ShufflePlan trace counter).  They still exist as
*views*, but the storage — or, for stats whose internal representation is
load-bearing (the store's fold-on-eviction write log), a snapshot
callback — is consolidated here so one call exports everything:

* :meth:`MetricsRegistry.snapshot` — versioned JSON document
  (``{"version": 1, "metrics": {...}}``), the machine-readable surface
  ``session.metrics()``/``frontend.metrics()`` return.
* :meth:`MetricsRegistry.prometheus_text` — Prometheus text exposition
  (``# TYPE``/``# HELP`` + samples), scrape-ready.

Instruments are **counters** (monotone), **gauges** (set/add) and
**fixed-bucket histograms** (cumulative ``le`` buckets + sum + count).
All are thread-safe with one tiny per-instrument lock held only around
the numeric update — no global lock on any hot path.  Same
``(name, labels)`` always resolves to the same instrument, so components
re-created per session (planners, frontends) attribute their series with
an instance label instead of colliding.

Callbacks (:meth:`register_callback`) contribute computed samples at
snapshot time; registrants are held by weakref so short-lived owners
(a test's Session) never pin or pollute the registry after death.
"""

from __future__ import annotations

import json
import threading
import time
import weakref
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "METRICS_SCHEMA_VERSION", "validate_snapshot",
           "DEFAULT_BUCKETS"]

#: schema version stamped into every JSON snapshot; loaders must tolerate
#: (skip + report) documents from a future version
METRICS_SCHEMA_VERSION = 1

#: latency-ish default buckets (seconds): 100µs … 10s, log-spaced
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0,
                   10.0)

Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[Dict[str, str]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Instrument:
    """Common shell: identity + its own cheap lock."""

    kind = "untyped"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self._lock = threading.Lock()

    @property
    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)


class Counter(_Instrument):
    """Monotone counter.  ``inc()`` only; decrements raise."""

    kind = "counter"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        super().__init__(name, labels, help)
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (v={v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        yield self.name, self.labels, self.value


class Gauge(_Instrument):
    """Point-in-time value: ``set()`` / ``add()`` (either direction)."""

    kind = "gauge"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        super().__init__(name, labels, help)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        yield self.name, self.labels, self.value


class Histogram(_Instrument):
    """Fixed-bucket histogram (Prometheus classic shape): per-bucket
    cumulative counts over static upper bounds, plus sum and count."""

    kind = "histogram"

    def __init__(self, name: str, labels: Labels, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, labels, help)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bs
        self._counts = [0] * (len(bs) + 1)       # +inf tail bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # linear scan: bucket lists are short (~12) and the loop is inside
        # the per-instrument lock for exact concurrent totals
        with self._lock:
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self._counts[i] += 1
                    break
            else:
                self._counts[-1] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            s, c = self._sum, self._count
        cum, out = 0, []
        for b, n in zip(self.buckets, counts[:-1]):
            cum += n
            out.append((b, cum))
        return {"buckets": out, "inf": c, "sum": s, "count": c}

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        snap = self.snapshot()
        for b, cum in snap["buckets"]:
            yield (self.name + "_bucket",
                   self.labels + (("le", _fmt_float(b)),), float(cum))
        yield (self.name + "_bucket", self.labels + (("le", "+Inf"),),
               float(snap["inf"]))
        yield self.name + "_sum", self.labels, float(snap["sum"])
        yield self.name + "_count", self.labels, float(snap["count"])


def _fmt_float(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Get-or-create instrument registry + exporters."""

    def __init__(self):
        self._instruments: Dict[Tuple[str, Labels], _Instrument] = {}
        self._lock = threading.Lock()            # registration only
        # weakref'd (owner, fn) callbacks: fn(owner) -> iterable of
        # (name, labels-dict, value) computed samples
        self._callbacks: List[Tuple[weakref.ref, Callable]] = []

    # -- registration --------------------------------------------------------
    def _get(self, cls, name: str, labels: Optional[Dict[str, str]],
             help: str, **kw) -> _Instrument:
        key = (name, _labels_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, key[1], help=help, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels, help, buckets=buckets)

    def register_callback(self, owner: Any, fn: Callable) -> None:
        """Contribute computed samples at snapshot time: ``fn(owner)``
        yields ``(name, labels_dict, value)``.  ``owner`` is weakly held —
        when it dies the callback silently disappears."""
        with self._lock:
            self._callbacks.append((weakref.ref(owner), fn))

    # -- collection ----------------------------------------------------------
    def _collect(self) -> List[Tuple[str, Labels, float, str, str]]:
        """All samples: (name, labels, value, kind, help)."""
        with self._lock:
            instruments = list(self._instruments.values())
            callbacks = list(self._callbacks)
        out = []
        for inst in instruments:
            for name, labels, value in inst.samples():
                out.append((name, labels, value, inst.kind, inst.help))
        dead = False
        for ref, fn in callbacks:
            owner = ref()
            if owner is None:
                dead = True
                continue
            try:
                for name, labels, value in fn(owner):
                    out.append((name, _labels_key(labels), float(value),
                                "gauge", ""))
            except Exception:       # noqa: BLE001 — a broken callback must
                continue            # never take down a metrics scrape
        if dead:
            with self._lock:
                self._callbacks = [(r, f) for r, f in self._callbacks
                                   if r() is not None]
        return out

    # -- exporters -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Versioned JSON document of every sample (the
        ``session.metrics()`` payload)."""
        metrics: Dict[str, Any] = {}
        for name, labels, value, kind, _help in sorted(self._collect()):
            series = metrics.setdefault(name, {"type": kind, "samples": []})
            series["samples"].append({"labels": dict(labels),
                                      "value": value})
        return {"version": METRICS_SCHEMA_VERSION,
                "generated_unix_s": time.time(),
                "metrics": metrics}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, one block per metric name."""
        by_name: Dict[str, List] = {}
        meta: Dict[str, Tuple[str, str]] = {}
        for name, labels, value, kind, help in self._collect():
            base = name
            for suffix in ("_bucket", "_sum", "_count"):
                if kind == "histogram" and name.endswith(suffix):
                    base = name[:-len(suffix)]
                    break
            by_name.setdefault(base, []).append((name, labels, value))
            meta.setdefault(base, (kind, help))
        lines: List[str] = []
        for base in sorted(by_name):
            kind, help = meta[base]
            if help:
                lines.append(f"# HELP {base} {help}")
            lines.append(f"# TYPE {base} {kind}")
            # keep each instrument's native sample order — histogram
            # buckets must stay le-ascending with +Inf last, which a
            # lexicographic sort would scramble
            for name, labels, value in by_name[base]:
                if labels:
                    lab = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
                    lines.append(f"{name}{{{lab}}} {_fmt_value(value)}")
                else:
                    lines.append(f"{name} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def write_snapshot(self, path: str) -> Dict[str, Any]:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return snap

    # -- maintenance ---------------------------------------------------------
    def reset(self) -> None:
        """Drop every instrument and callback (tests)."""
        with self._lock:
            self._instruments.clear()
            self._callbacks.clear()


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def validate_snapshot(snap: Dict[str, Any]) -> Tuple[bool, str]:
    """Loader-side schema check for a metrics JSON snapshot: known
    versions pass; an unknown (newer) version is *reported*, not fatal —
    callers decide whether to best-effort parse."""
    v = snap.get("version")
    if v is None:
        return False, "snapshot has no 'version' field"
    if int(v) > METRICS_SCHEMA_VERSION:
        return False, (f"snapshot version {v} is newer than supported "
                       f"{METRICS_SCHEMA_VERSION}; fields may be missing")
    return True, ""


#: the process-global default registry (Sessions/Frontends use it unless
#: constructed with their own)
REGISTRY = MetricsRegistry()
