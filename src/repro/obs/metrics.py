"""MetricsRegistry — the one place runtime counters live (DESIGN §13).

Before this layer, every subsystem kept its own ad-hoc stats dict
(planner ``cache_stats()``, store ``write_stats()``/``io_stats``, serving
``stats()``, the device ShufflePlan trace counter).  They still exist as
*views*, but the storage — or, for stats whose internal representation is
load-bearing (the store's fold-on-eviction write log), a snapshot
callback — is consolidated here so one call exports everything:

* :meth:`MetricsRegistry.snapshot` — versioned JSON document
  (``{"version": 1, "metrics": {...}}``), the machine-readable surface
  ``session.metrics()``/``frontend.metrics()`` return.
* :meth:`MetricsRegistry.prometheus_text` — Prometheus text exposition
  (``# TYPE``/``# HELP`` + samples), scrape-ready.

Instruments are **counters** (monotone), **gauges** (set/add) and
**fixed-bucket histograms** (cumulative ``le`` buckets + sum + count).
All are thread-safe with one tiny per-instrument lock held only around
the numeric update — no global lock on any hot path.  Same
``(name, labels)`` always resolves to the same instrument, so components
re-created per session (planners, frontends) attribute their series with
an instance label instead of colliding.

Callbacks (:meth:`register_callback`) contribute computed samples at
snapshot time; registrants are held by weakref so short-lived owners
(a test's Session) never pin or pollute the registry after death.
"""

from __future__ import annotations

import json
import threading
import time
import weakref
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "METRICS_SCHEMA_VERSION", "validate_snapshot",
           "DEFAULT_BUCKETS", "merge_node_snapshots",
           "snapshot_prometheus_text", "parse_prometheus_text"]

#: schema version stamped into every JSON snapshot; loaders must tolerate
#: (skip + report) documents from a future version
METRICS_SCHEMA_VERSION = 1

#: latency-ish default buckets (seconds): 100µs … 10s, log-spaced
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0,
                   10.0)

Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[Dict[str, str]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Instrument:
    """Common shell: identity + its own cheap lock."""

    kind = "untyped"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self._lock = threading.Lock()

    @property
    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)


class Counter(_Instrument):
    """Monotone counter.  ``inc()`` only; decrements raise."""

    kind = "counter"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        super().__init__(name, labels, help)
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (v={v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        yield self.name, self.labels, self.value


class Gauge(_Instrument):
    """Point-in-time value: ``set()`` / ``add()`` (either direction)."""

    kind = "gauge"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        super().__init__(name, labels, help)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        yield self.name, self.labels, self.value


class Histogram(_Instrument):
    """Fixed-bucket histogram (Prometheus classic shape): per-bucket
    cumulative counts over static upper bounds, plus sum and count."""

    kind = "histogram"

    def __init__(self, name: str, labels: Labels, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, labels, help)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bs
        self._counts = [0] * (len(bs) + 1)       # +inf tail bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # linear scan: bucket lists are short (~12) and the loop is inside
        # the per-instrument lock for exact concurrent totals
        with self._lock:
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self._counts[i] += 1
                    break
            else:
                self._counts[-1] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            s, c = self._sum, self._count
        cum, out = 0, []
        for b, n in zip(self.buckets, counts[:-1]):
            cum += n
            out.append((b, cum))
        return {"buckets": out, "inf": c, "sum": s, "count": c}

    def samples(self) -> Iterable[Tuple[str, Labels, float]]:
        snap = self.snapshot()
        for b, cum in snap["buckets"]:
            yield (self.name + "_bucket",
                   self.labels + (("le", _fmt_float(b)),), float(cum))
        yield (self.name + "_bucket", self.labels + (("le", "+Inf"),),
               float(snap["inf"]))
        yield self.name + "_sum", self.labels, float(snap["sum"])
        yield self.name + "_count", self.labels, float(snap["count"])


def _fmt_float(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Get-or-create instrument registry + exporters."""

    def __init__(self):
        self._instruments: Dict[Tuple[str, Labels], _Instrument] = {}
        self._lock = threading.Lock()            # registration only
        # weakref'd (owner, fn) callbacks: fn(owner) -> iterable of
        # (name, labels-dict, value) computed samples
        self._callbacks: List[Tuple[weakref.ref, Callable]] = []

    # -- registration --------------------------------------------------------
    def _get(self, cls, name: str, labels: Optional[Dict[str, str]],
             help: str, **kw) -> _Instrument:
        key = (name, _labels_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, key[1], help=help, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels, help, buckets=buckets)

    def register_callback(self, owner: Any, fn: Callable) -> None:
        """Contribute computed samples at snapshot time: ``fn(owner)``
        yields ``(name, labels_dict, value)``.  ``owner`` is weakly held —
        when it dies the callback silently disappears."""
        with self._lock:
            self._callbacks.append((weakref.ref(owner), fn))

    # -- collection ----------------------------------------------------------
    def _collect(self) -> List[Tuple[str, Labels, float, str, str]]:
        """All samples: (name, labels, value, kind, help)."""
        with self._lock:
            instruments = list(self._instruments.values())
            callbacks = list(self._callbacks)
        out = []
        for inst in instruments:
            for name, labels, value in inst.samples():
                out.append((name, labels, value, inst.kind, inst.help))
        dead = False
        for ref, fn in callbacks:
            owner = ref()
            if owner is None:
                dead = True
                continue
            try:
                for name, labels, value in fn(owner):
                    out.append((name, _labels_key(labels), float(value),
                                "gauge", ""))
            except Exception:       # noqa: BLE001 — a broken callback must
                continue            # never take down a metrics scrape
        if dead:
            with self._lock:
                self._callbacks = [(r, f) for r, f in self._callbacks
                                   if r() is not None]
        return out

    # -- exporters -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Versioned JSON document of every sample (the
        ``session.metrics()`` payload)."""
        metrics: Dict[str, Any] = {}
        for name, labels, value, kind, _help in sorted(self._collect()):
            series = metrics.setdefault(name, {"type": kind, "samples": []})
            series["samples"].append({"labels": dict(labels),
                                      "value": value})
        return {"version": METRICS_SCHEMA_VERSION,
                "generated_unix_s": time.time(),
                "metrics": metrics}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, one block per metric name."""
        by_name: Dict[str, List] = {}
        meta: Dict[str, Tuple[str, str]] = {}
        for name, labels, value, kind, help in self._collect():
            base = name
            for suffix in ("_bucket", "_sum", "_count"):
                if kind == "histogram" and name.endswith(suffix):
                    base = name[:-len(suffix)]
                    break
            by_name.setdefault(base, []).append((name, labels, value))
            meta.setdefault(base, (kind, help))
        lines: List[str] = []
        for base in sorted(by_name):
            kind, help = meta[base]
            if help:
                lines.append(f"# HELP {base} {help}")
            lines.append(f"# TYPE {base} {kind}")
            # keep each instrument's native sample order — histogram
            # buckets must stay le-ascending with +Inf last, which a
            # lexicographic sort would scramble
            for name, labels, value in by_name[base]:
                if labels:
                    lab = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
                    lines.append(f"{name}{{{lab}}} {_fmt_value(value)}")
                else:
                    lines.append(f"{name} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def write_snapshot(self, path: str) -> Dict[str, Any]:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return snap

    # -- maintenance ---------------------------------------------------------
    def reset(self) -> None:
        """Drop every instrument and callback (tests)."""
        with self._lock:
            self._instruments.clear()
            self._callbacks.clear()


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def validate_snapshot(snap: Dict[str, Any]) -> Tuple[bool, str]:
    """Loader-side schema check for a metrics JSON snapshot: known
    versions pass; an unknown (newer) version is *reported*, not fatal —
    callers decide whether to best-effort parse."""
    v = snap.get("version")
    if v is None:
        return False, "snapshot has no 'version' field"
    if int(v) > METRICS_SCHEMA_VERSION:
        return False, (f"snapshot version {v} is newer than supported "
                       f"{METRICS_SCHEMA_VERSION}; fields may be missing")
    return True, ""


# ---------------------------------------------------------------------------
# cluster metrics aggregation (DESIGN §15): merge per-node JSON snapshots
# into one node-labeled view, render any snapshot as Prometheus text, and
# strictly parse that text back (the round-trip contract tests pin).
# ---------------------------------------------------------------------------

def merge_node_snapshots(by_node: Dict[str, Dict[str, Any]]
                         ) -> Dict[str, Any]:
    """Merge per-node metrics snapshots into ONE snapshot document where
    every sample carries a ``node`` label.  Future-version snapshots are
    skipped (reported under ``skipped_nodes``), matching the tolerant
    loader contract everywhere else."""
    merged: Dict[str, Any] = {}
    skipped: List[str] = []
    for node in sorted(by_node):
        snap = by_node[node]
        ok, _why = validate_snapshot(snap)
        if not ok:
            skipped.append(node)
            continue
        for name, series in snap.get("metrics", {}).items():
            out = merged.setdefault(
                name, {"type": series.get("type", "untyped"), "samples": []})
            for s in series.get("samples", []):
                labels = dict(s.get("labels", {}))
                labels["node"] = node
                out["samples"].append({"labels": labels,
                                       "value": s.get("value", 0.0)})
    doc: Dict[str, Any] = {"version": METRICS_SCHEMA_VERSION,
                           "generated_unix_s": time.time(),
                           "nodes": sorted(set(by_node) - set(skipped)),
                           "metrics": merged}
    if skipped:
        doc["skipped_nodes"] = skipped
    return doc


def _le_sort_key(labels: Dict[str, str]):
    le = labels.get("le", "")
    v = float("inf") if le == "+Inf" else float(le)
    return v


def snapshot_prometheus_text(snap: Dict[str, Any]) -> str:
    """Render a metrics JSON snapshot (live or merged) as Prometheus text.

    The JSON snapshot sorts samples lexicographically, which scrambles
    histogram bucket order (``"+Inf" < "0.001"`` as strings) — this
    renderer re-groups buckets per label set and re-sorts ``le``
    numerically with ``+Inf`` last, so the text output honors the
    exposition-format ordering contract regardless of source order."""
    ok, why = validate_snapshot(snap)
    if not ok:
        raise ValueError(f"cannot render snapshot: {why}")
    metrics = snap.get("metrics", {})
    # group histogram series (name_bucket/_sum/_count) under their base
    bases: Dict[str, Dict[str, Any]] = {}
    for name, series in metrics.items():
        base = name
        if series.get("type") == "histogram":
            for suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(suffix):
                    base = name[:-len(suffix)]
                    break
        entry = bases.setdefault(base, {"type": series.get("type",
                                                           "untyped"),
                                        "series": {}})
        entry["series"][name] = series
    lines: List[str] = []
    for base in sorted(bases):
        entry = bases[base]
        kind = entry["type"]
        lines.append(f"# TYPE {base} {kind}")
        if kind == "histogram":
            _render_histogram(lines, base, entry["series"])
            continue
        for name in sorted(entry["series"]):
            samples = entry["series"][name].get("samples", [])
            for s in sorted(samples,
                            key=lambda s: sorted(s.get("labels",
                                                       {}).items())):
                lines.append(_sample_line(name, s.get("labels", {}),
                                          s.get("value", 0.0)))
    return "\n".join(lines) + "\n"


def _render_histogram(lines: List[str], base: str,
                      series: Dict[str, Any]) -> None:
    buckets = series.get(base + "_bucket", {}).get("samples", [])
    sums = series.get(base + "_sum", {}).get("samples", [])
    counts = series.get(base + "_count", {}).get("samples", [])

    def group_key(s):
        return tuple(sorted((k, v) for k, v in s.get("labels", {}).items()
                            if k != "le"))

    groups: Dict[Tuple, List] = {}
    for s in buckets:
        groups.setdefault(group_key(s), []).append(s)
    by_key_sum = {group_key(s): s for s in sums}
    by_key_count = {group_key(s): s for s in counts}
    for key in sorted(groups):
        for s in sorted(groups[key], key=lambda s: _le_sort_key(
                s.get("labels", {}))):
            lines.append(_sample_line(base + "_bucket",
                                      s.get("labels", {}),
                                      s.get("value", 0.0)))
        if key in by_key_sum:
            s = by_key_sum[key]
            lines.append(_sample_line(base + "_sum", s.get("labels", {}),
                                      s.get("value", 0.0)))
        if key in by_key_count:
            s = by_key_count[key]
            lines.append(_sample_line(base + "_count", s.get("labels", {}),
                                      s.get("value", 0.0)))


def _sample_line(name: str, labels: Dict[str, str], value: Any) -> str:
    if labels:
        lab = ",".join(f'{k}="{_escape(str(v))}"'
                       for k, v in sorted(labels.items()))
        return f"{name}{{{lab}}} {_fmt_value(float(value))}"
    return f"{name} {_fmt_value(float(value))}"


def _unescape(v: str) -> str:
    out: List[str] = []
    i = 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            if nxt == "\\":
                out.append("\\")
            elif nxt == '"':
                out.append('"')
            elif nxt == "n":
                out.append("\n")
            else:
                raise ValueError(f"bad escape \\{nxt} in label value {v!r}")
            i += 2
            continue
        out.append(c)
        i += 1
    return "".join(out)


def _parse_labels(body: str) -> Dict[str, str]:
    """Parse the ``{k="v",...}`` body with full escape handling."""
    labels: Dict[str, str] = {}
    i, n = 0, len(body)
    while i < n:
        j = body.index("=", i)
        key = body[i:j].strip()
        if not key or not key.replace("_", "a").isalnum():
            raise ValueError(f"bad label name {key!r}")
        if j + 1 >= n or body[j + 1] != '"':
            raise ValueError(f"label {key!r} value is not quoted")
        k = j + 2
        raw: List[str] = []
        while k < n:
            c = body[k]
            if c == "\\":
                raw.append(body[k:k + 2])
                k += 2
                continue
            if c == '"':
                break
            raw.append(c)
            k += 1
        else:
            raise ValueError("unterminated label value")
        if key in labels:
            raise ValueError(f"duplicate label {key!r}")
        labels[key] = _unescape("".join(raw))
        i = k + 1
        if i < n:
            if body[i] != ",":
                raise ValueError(f"expected ',' at {body[i:]!r}")
            i += 1
    return labels


def parse_prometheus_text(text: str) -> Dict[str, Any]:
    """Strict parser for the text exposition format.  Returns
    ``{"types": {base: kind}, "samples": [(name, labels, value)]}`` and
    raises ``ValueError`` on any violation of the contract our emitters
    promise: parseable sample lines, a ``# TYPE`` line preceding each
    metric's samples, no duplicate ``(name, labels)`` sample, histogram
    buckets in ascending ``le`` order with ``+Inf`` last and a bucket
    count matching ``_count`` per label set."""
    types: Dict[str, str] = {}
    samples: List[Tuple[str, Dict[str, str], float]] = []
    seen: set = set()
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise ValueError(f"line {lineno}: malformed TYPE line")
            _h, _t, base, kind = parts
            if base in types:
                raise ValueError(f"line {lineno}: duplicate TYPE {base}")
            if kind not in ("counter", "gauge", "histogram", "untyped"):
                raise ValueError(f"line {lineno}: unknown kind {kind!r}")
            types[base] = kind
            continue
        if line.startswith("#"):
            continue                           # HELP / comments
        # sample line: name{labels} value  |  name value
        if "{" in line:
            name, rest = line.split("{", 1)
            body, tail = rest.rsplit("}", 1)
            labels = _parse_labels(body)
            value_str = tail.strip()
        else:
            try:
                name, value_str = line.rsplit(None, 1)
            except ValueError:
                raise ValueError(f"line {lineno}: malformed sample "
                                 f"{line!r}") from None
            labels = {}
        name = name.strip()
        if not name or not name.replace("_", "a").replace(":",
                                                          "a").isalnum():
            raise ValueError(f"line {lineno}: bad metric name {name!r}")
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types \
                    and types[name[:-len(suffix)]] == "histogram":
                base = name[:-len(suffix)]
                break
        if base not in types:
            raise ValueError(f"line {lineno}: sample {name!r} has no "
                             f"preceding # TYPE line")
        if value_str == "+Inf":
            value = float("inf")
        else:
            value = float(value_str)
        key = (name, tuple(sorted(labels.items())))
        if key in seen:
            raise ValueError(f"line {lineno}: duplicate sample {key}")
        seen.add(key)
        samples.append((name, labels, value))
    _check_histograms(types, samples)
    return {"types": types, "samples": samples}


def _check_histograms(types: Dict[str, str],
                      samples: List[Tuple[str, Dict[str, str], float]]
                      ) -> None:
    for base, kind in types.items():
        if kind != "histogram":
            continue
        groups: Dict[Tuple, List[Tuple[str, float]]] = {}
        counts: Dict[Tuple, float] = {}
        for name, labels, value in samples:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            if name == base + "_bucket":
                groups.setdefault(key, []).append(
                    (labels.get("le", ""), value))
            elif name == base + "_count":
                counts[key] = value
        for key, rows in groups.items():
            les = [float("inf") if le == "+Inf" else float(le)
                   for le, _ in rows]
            if les != sorted(les) or len(set(les)) != len(les):
                raise ValueError(
                    f"{base}{dict(key)}: buckets not in ascending le order")
            if not les or les[-1] != float("inf"):
                raise ValueError(f"{base}{dict(key)}: +Inf bucket missing "
                                 "or not last")
            cums = [v for _, v in rows]
            if cums != sorted(cums):
                raise ValueError(f"{base}{dict(key)}: bucket counts not "
                                 "cumulative")
            if key in counts and counts[key] != cums[-1]:
                raise ValueError(f"{base}{dict(key)}: _count "
                                 f"{counts[key]} != +Inf bucket {cums[-1]}")


#: the process-global default registry (Sessions/Frontends use it unless
#: constructed with their own)
REGISTRY = MetricsRegistry()
