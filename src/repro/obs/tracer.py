"""Span tracer — the timeline half of the observability layer (DESIGN §13).

One process-global :class:`Tracer` records **spans**: named, timed
intervals with parent↔child links, organized per thread via a
thread-local context stack and stamped off one monotonic clock
(``time.perf_counter``).  Finished spans land in a bounded ring buffer
(old spans fall off; a long-lived service never grows without bound) and
export as Chrome ``trace_event`` JSON (:mod:`repro.obs.export`) loadable
in Perfetto / ``chrome://tracing``.

Overhead contract: tracing is **off by default** and the disabled path is
one module-global load plus one shared no-op object — no allocation, no
clock read, no lock (``bench_overhead.tracing_overhead`` prices it
against the plan-cache-hit path and asserts <2%).  Three modes:

``off``      every ``span()`` call returns the shared no-op span.
``sampled``  1-in-``sample_every`` *root* spans record; children follow
             their root's verdict, so sampled traces stay complete trees.
``full``     everything records.

Cross-thread parenting: a span does not survive a thread handoff by
itself (the context stack is thread-local), so the submitting side
captures ``tracer.context()`` and the worker runs inside
``with tracer.attach(ctx):`` — child spans then parent to the capturing
span across the pool boundary, and the exporter draws the handoff as a
Chrome flow arrow.  The serving tier (submit → ticket worker) and the
Autopilot (facade → optimizer thread ticks) both use this.

Cross-*process* parenting (DESIGN §15) works the same way, one
serialization step removed: :meth:`TraceContext.to_wire` /
:meth:`TraceContext.from_wire` move a context through any dict carrier
(a JSON file under the store, or the ``LACHESIS_TRACE_CONTEXT`` env var
for spawned subprocesses), and the receiving process runs under
``tracer.attach(ctx)`` exactly as a worker thread would.  Because
``perf_counter`` has a per-process epoch, each context also carries a
wall-clock capture stamp (``captured_unix``) and each process's span
spill records a (perf, unix) anchor pair — the merge step in
:mod:`repro.obs.export` rebases every process onto the shared wall
clock and draws the handoff as a cross-process flow arrow.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional

__all__ = ["Span", "TraceContext", "Tracer", "TRACER", "TRACE_ENV_VAR",
           "span", "configure", "enable", "disable", "tracing_mode",
           "finished_spans", "open_spans", "clear_spans"]

#: env-var carrier for a wire-format TraceContext (spawned subprocesses)
TRACE_ENV_VAR = "LACHESIS_TRACE_CONTEXT"

#: wire-format schema version for serialized TraceContexts
CONTEXT_WIRE_VERSION = 1

_ids = itertools.count(1)            # span ids (atomic under the GIL)
_trace_ids = itertools.count(1)      # trace ids (one per root span)


@dataclass
class Span:
    """One finished (or in-flight) timed interval."""
    name: str
    cat: str
    span_id: int
    parent_id: Optional[int]
    trace_id: int
    tid: int                          # OS thread ident
    thread_name: str
    t0: float                         # perf_counter at enter
    t1: Optional[float] = None        # perf_counter at exit (None = open)
    args: Dict[str, Any] = field(default_factory=dict)
    # set when the parent link crosses a thread handoff (tracer.attach):
    # (parent span id, parent tid, capture time) — the exporter emits a
    # Chrome flow arrow from there to this span's start
    flow_from: Optional["TraceContext"] = None

    @property
    def dur_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def set(self, **kw) -> "Span":
        """Attach key=value annotations (shown in the trace viewer)."""
        self.args.update(kw)
        return self

    # -- context manager -----------------------------------------------------
    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        TRACER._finish(self)
        return False


class _NullSpan:
    """The shared disabled span: every operation is a no-op returning
    ``self`` so instrumentation sites never branch on the mode."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **kw) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _SuppressSpan:
    """Root-not-sampled marker: suppresses child recording for its extent
    (so a sampled tracer emits whole trees or nothing)."""
    __slots__ = ("_local",)

    def __init__(self, local):
        self._local = local

    def __enter__(self) -> "_SuppressSpan":
        self._local.suppress += 1
        return self

    def __exit__(self, *exc) -> bool:
        self._local.suppress -= 1
        return False

    def set(self, **kw) -> "_SuppressSpan":
        return self


@dataclass(frozen=True)
class TraceContext:
    """Capturable link target for cross-thread (and, serialized, for
    cross-process) parenting.  Immutable.

    ``captured_at`` is the capturing process's ``perf_counter`` — only
    meaningful inside that process.  ``captured_unix`` is the wall-clock
    stamp taken at the same instant, the coordinate the cross-process
    merge uses; ``process`` names the capturing process so the merged
    trace can route the flow arrow back to its timeline.
    """
    trace_id: int
    span_id: int
    tid: int
    thread_name: str
    captured_at: float
    process: str = ""
    captured_unix: float = 0.0

    # -- wire format (cross-process carrier) ---------------------------------
    def to_wire(self) -> Dict[str, Any]:
        """Serializable dict form (versioned; survives JSON round-trip)."""
        return {"v": CONTEXT_WIRE_VERSION, "trace_id": self.trace_id,
                "span_id": self.span_id, "tid": self.tid,
                "thread_name": self.thread_name, "process": self.process,
                "captured_unix": self.captured_unix}

    @classmethod
    def from_wire(cls, wire: Mapping[str, Any]) -> "TraceContext":
        """Rebuild a context from its wire dict.  Tolerant of *older*
        wire versions (missing fields default); a *newer* version raises
        so a mixed-version cluster fails loudly instead of mis-linking."""
        v = int(wire.get("v", 1))
        if v > CONTEXT_WIRE_VERSION:
            raise ValueError(
                f"trace context wire version {v} is newer than supported "
                f"{CONTEXT_WIRE_VERSION}")
        return cls(trace_id=int(wire["trace_id"]),
                   span_id=int(wire["span_id"]),
                   tid=int(wire.get("tid", 0)),
                   thread_name=str(wire.get("thread_name", "")),
                   captured_at=0.0,
                   process=str(wire.get("process", "")),
                   captured_unix=float(wire.get("captured_unix", 0.0)))

    def to_env(self) -> Dict[str, str]:
        """Env-var carrier: merge into a child process's environment."""
        return {TRACE_ENV_VAR: json.dumps(self.to_wire())}

    @classmethod
    def from_env(cls, environ: Optional[Mapping[str, str]] = None
                 ) -> Optional["TraceContext"]:
        """Read the env-var carrier (None when absent or unparseable)."""
        raw = (environ if environ is not None else os.environ).get(
            TRACE_ENV_VAR)
        if not raw:
            return None
        try:
            return cls.from_wire(json.loads(raw))
        except (ValueError, KeyError, TypeError):
            return None


class _Local(threading.local):
    def __init__(self):
        self.stack: List[Span] = []            # open spans, innermost last
        self.suppress = 0                      # >0 → root was not sampled
        self.attached: Optional[TraceContext] = None


class Tracer:
    """Process-global span recorder (see module docstring)."""

    def __init__(self, buffer: int = 65536):
        self.mode = "off"
        self.sample_every = 16
        self.process = f"pid-{os.getpid()}"    # label for cross-process merge
        self._buffer = int(buffer)
        self._spans: List[Span] = []
        self._open: Dict[int, Span] = {}       # span_id → in-flight span
        self._lock = threading.Lock()          # guards ring buffer + _open
        self._local = _Local()
        self._sample_clock = itertools.count()
        self.dropped = 0                       # spans evicted from the ring

    # -- configuration -------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    def configure(self, mode: Optional[str] = None,
                  buffer: Optional[int] = None,
                  sample_every: Optional[int] = None,
                  process: Optional[str] = None) -> "Tracer":
        global _OFF
        if mode is not None:
            if mode not in ("off", "sampled", "full"):
                raise ValueError(f"unknown tracing mode {mode!r} "
                                 "(use 'off', 'sampled' or 'full')")
            self.mode = mode
        if buffer is not None:
            if buffer < 1:
                raise ValueError("trace buffer must be >= 1")
            self._buffer = int(buffer)
            with self._lock:
                self._evict()
        if sample_every is not None:
            if sample_every < 1:
                raise ValueError("sample_every must be >= 1")
            self.sample_every = int(sample_every)
        if process is not None:
            if not process:
                raise ValueError("process label must be non-empty")
            self.process = str(process)
        _OFF = self.mode == "off"
        return self

    # -- span lifecycle ------------------------------------------------------
    def span(self, name: str, cat: str = "", **args):
        """Start a span (use as a context manager).  Near-free when off."""
        if _OFF:
            return NULL_SPAN
        return self._start(name, cat, args)

    def _start(self, name: str, cat: str, args: Dict[str, Any]):
        local = self._local
        if local.suppress:
            return _SuppressSpan(local)
        parent = local.stack[-1] if local.stack else None
        flow = None
        if parent is None and local.attached is not None:
            ctx = local.attached
            trace_id, parent_id = ctx.trace_id, ctx.span_id
            flow = ctx
        elif parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            # a fresh root: sampling decides whether this tree records
            if self.mode == "sampled" and \
                    next(self._sample_clock) % self.sample_every:
                return _SuppressSpan(local)
            trace_id, parent_id = next(_trace_ids), None
        t = threading.current_thread()
        sp = Span(name=name, cat=cat, span_id=next(_ids),
                  parent_id=parent_id, trace_id=trace_id,
                  tid=t.ident or 0, thread_name=t.name,
                  t0=time.perf_counter(), args=dict(args), flow_from=flow)
        local.stack.append(sp)
        with self._lock:
            self._open[sp.span_id] = sp
        return sp

    def _finish(self, sp: Span) -> None:
        sp.t1 = time.perf_counter()
        stack = self._local.stack
        # normal case: sp is the innermost open span on this thread
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:                      # mismatched exits — recover
            stack.remove(sp)
        with self._lock:
            self._open.pop(sp.span_id, None)
            self._spans.append(sp)
            self._evict()

    def _evict(self) -> None:
        # caller holds _lock
        if len(self._spans) > self._buffer:
            n = len(self._spans) - self._buffer
            del self._spans[:n]
            self.dropped += n

    # -- cross-thread parenting ----------------------------------------------
    def context(self) -> Optional[TraceContext]:
        """Capture the current span as a link target for another thread
        (None when nothing is recording here)."""
        if _OFF:
            return None
        local = self._local
        if local.suppress:
            return None
        if local.stack:
            sp = local.stack[-1]
            t = threading.current_thread()
            return TraceContext(trace_id=sp.trace_id, span_id=sp.span_id,
                                tid=t.ident or 0, thread_name=t.name,
                                captured_at=time.perf_counter(),
                                process=self.process,
                                captured_unix=time.time())
        return local.attached

    def attach(self, ctx: Optional[TraceContext]):
        """Run a block with ``ctx`` as the adopted parent: root spans
        opened inside parent to the capturing span (even though it lives
        on another thread) and export with a flow arrow."""
        return _Attach(self._local, ctx)

    # -- inspection ----------------------------------------------------------
    def finished(self) -> List[Span]:
        """Snapshot of the ring buffer (closed spans, oldest first)."""
        with self._lock:
            return list(self._spans)

    def open(self) -> List[Span]:
        """Snapshot of currently in-flight spans (any thread).  A crash
        dump of these is what lets an aborted process's last span survive
        into the merged trace (DESIGN §15)."""
        with self._lock:
            return list(self._open.values())

    def anchor(self) -> Dict[str, Any]:
        """A (perf_counter, wall-clock) pair stamped at the same instant —
        the coordinate transform the cross-process merge needs to rebase
        this process's spans onto the shared wall clock."""
        return {"process": self.process, "pid": os.getpid(),
                "anchor_perf": time.perf_counter(),
                "anchor_unix": time.time()}

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._open.clear()
            self.dropped = 0

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            n = len(self._spans)
            n_open = len(self._open)
        return {"mode": self.mode, "buffered": n, "open": n_open,
                "dropped": self.dropped, "buffer": self._buffer,
                "sample_every": self.sample_every, "process": self.process}


class _Attach:
    __slots__ = ("_local", "_ctx", "_prev")

    def __init__(self, local: _Local, ctx: Optional[TraceContext]):
        self._local = local
        self._ctx = ctx

    def __enter__(self):
        self._prev = self._local.attached
        self._local.attached = self._ctx
        return self

    def __exit__(self, *exc) -> bool:
        self._local.attached = self._prev
        return False


#: the process-global tracer every instrumentation site records into
TRACER = Tracer()
_OFF = True         # mirrors TRACER.mode — the one-load disabled check


def span(name: str, cat: str = "", **args):
    """Module-level shortcut: ``with span("exec.scan", dataset=...)``."""
    if _OFF:
        return NULL_SPAN
    return TRACER._start(name, cat, args)


def configure(**kw) -> Tracer:
    return TRACER.configure(**kw)


def enable(mode: str = "full", **kw) -> Tracer:
    return TRACER.configure(mode=mode, **kw)


def disable() -> Tracer:
    return TRACER.configure(mode="off")


def tracing_mode() -> str:
    return TRACER.mode


def finished_spans() -> List[Span]:
    return TRACER.finished()


def open_spans() -> List[Span]:
    return TRACER.open()


def clear_spans() -> None:
    TRACER.clear()
