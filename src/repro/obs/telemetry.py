"""Durable telemetry history — the (state, action, reward) substrate
(DESIGN §15).

A :class:`TelemetryStore` lives under the store root
(``<root>/telemetry/``) and appends one :class:`RunProfile` record per
executed run — wall, shuffle and IO seconds, plan-cache hit/miss,
retrace count, padded/valid bytes, placement epoch and the per-dataset
generation pins the plan keyed on — plus per-tick Autopilot snapshots.
Unlike ``decisions.log`` (an audit trail, fsync'd per record), telemetry
is advisory: appends flush but do not fsync, and the file is **bounded**
— when it outgrows ``max_records`` plus slack, a compaction folds the
evicted run records into one aggregate ``summary`` record and atomically
rewrites the file, so a long-lived service never grows it without bound
(the same fold-into-aggregate idiom the Observer's HistoryStore uses).

The append path is the per-run overhead: one ``json.dumps`` + one write
on an already-open handle, priced by ``bench_overhead.telemetry_overhead``
against the plan-cache-hit wall (<2% budget, same contract as tracing).

This file is exactly the stream ROADMAP item 4's DRL advisor trains
from: each record pairs the observed state (bytes, skew, epoch), the
decision context (generations, decision ids in why-records keyed by the
same epoch), and the reward (wall seconds).

The same directory also aggregates **cluster metrics**: each process
exports its registry snapshot to ``metrics-<node>.json``
(:meth:`TelemetryStore.write_node_metrics`) and
:meth:`TelemetryStore.cluster_metrics` merges them into one snapshot
with a ``node`` label on every sample, renderable as Prometheus text.
"""

from __future__ import annotations

import json
import os
import threading
import time
import warnings
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from .metrics import (MetricsRegistry, merge_node_snapshots,
                      snapshot_prometheus_text)
from .tracer import TraceContext

__all__ = ["RunProfile", "TelemetryStore", "TELEMETRY_SCHEMA_VERSION"]

#: schema version stamped into every telemetry record; the loader skips
#: (and warns about) records from a future version, tolerates older ones
TELEMETRY_SCHEMA_VERSION = 1


@dataclass
class RunProfile:
    """One executed run, profiled.  All fields default so records written
    by older versions (or hand-rolled in tests) still load."""
    t: float = 0.0                    # wall-clock stamp (unix seconds)
    workload: str = ""                # Workload app_id
    process: str = ""                 # tracer process label
    wall_s: float = 0.0
    shuffle_s: float = 0.0
    io_s: float = 0.0
    planning_s: float = 0.0
    plan_cache_hit: Optional[bool] = None
    retraces: int = 0                 # device traces added by this run
    shuffles_performed: int = 0
    shuffles_elided: int = 0
    shuffle_bytes: int = 0
    input_bytes: int = 0
    output_bytes: int = 0
    io_bytes: int = 0                 # storage bytes rehydrated
    padded_bytes: int = 0
    valid_bytes: int = 0
    placement_epoch: int = -1         # cluster directory epoch (-1 = none)
    generations: Dict[str, int] = field(default_factory=dict)

    def to_record(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_record(cls, rec: Dict[str, Any]) -> "RunProfile":
        known = {f for f in cls.__dataclass_fields__}  # noqa: C416
        return cls(**{k: v for k, v in rec.items() if k in known})


class TelemetryStore:
    """Bounded, compacting JSONL history under ``<root>/telemetry/``."""

    def __init__(self, root: str, max_records: int = 4096,
                 compact_slack: Optional[int] = None):
        if max_records < 1:
            raise ValueError("max_records must be >= 1")
        self.dir = os.path.join(root, "telemetry")
        os.makedirs(self.dir, exist_ok=True)
        self.path = os.path.join(self.dir, "runs.jsonl")
        self.max_records = int(max_records)
        # compact lazily: let the file overshoot by `slack` records so the
        # rewrite amortizes instead of firing on every append past the cap
        self.compact_slack = (max(1, max_records // 4)
                              if compact_slack is None else int(compact_slack))
        self._lock = threading.Lock()
        self._f = None                        # lazily-opened append handle
        self._count = self._count_existing()
        self._seq = self._count
        self.appends = 0
        self.compactions = 0

    # -- internals -----------------------------------------------------------
    def _count_existing(self) -> int:
        try:
            with open(self.path, "rb") as f:
                return sum(1 for _ in f)
        except OSError:
            return 0

    def _handle(self):
        # caller holds _lock
        if self._f is None:
            self._f = open(self.path, "a")
        return self._f

    def _append(self, rec: Dict[str, Any]) -> None:
        line = json.dumps(rec) + "\n"
        with self._lock:
            f = self._handle()
            f.write(line)
            f.flush()                         # advisory: no fsync
            self._count += 1
            self.appends += 1
            if self._count > self.max_records + self.compact_slack:
                self._compact_locked()

    # -- recording -----------------------------------------------------------
    def record_run(self, profile: RunProfile) -> None:
        """Append one per-run profile (the hot path — bounded cost)."""
        rec = profile.to_record()
        rec["v"] = TELEMETRY_SCHEMA_VERSION
        rec["kind"] = "run"
        self._seq += 1
        rec["seq"] = self._seq
        self._append(rec)

    def record_tick(self, payload: Dict[str, Any]) -> None:
        """Append one Autopilot tick snapshot."""
        rec = dict(payload)
        rec["v"] = TELEMETRY_SCHEMA_VERSION
        rec["kind"] = "tick"
        rec.setdefault("t", time.time())
        self._seq += 1
        rec["seq"] = self._seq
        self._append(rec)

    # -- reading -------------------------------------------------------------
    def records(self, kind: Optional[str] = None,
                limit: Optional[int] = None) -> List[Dict[str, Any]]:
        """All records oldest-first (tolerant loader: torn lines skipped,
        future-version records skipped with one warning)."""
        out: List[Dict[str, Any]] = []
        warned = False
        try:
            with open(self.path) as f:
                lines = f.readlines()
        except OSError:
            return out
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue                      # torn tail — ignore
            if not isinstance(rec, dict):
                continue
            if int(rec.get("v", 1)) > TELEMETRY_SCHEMA_VERSION:
                if not warned:
                    warnings.warn(
                        f"telemetry record version {rec.get('v')} > "
                        f"supported {TELEMETRY_SCHEMA_VERSION}; skipping",
                        stacklevel=2)
                    warned = True
                continue
            if kind is not None and rec.get("kind") != kind:
                continue
            out.append(rec)
        if limit is not None:
            out = out[-int(limit):]
        return out

    def run_profiles(self, limit: Optional[int] = None) -> List[RunProfile]:
        return [RunProfile.from_record(r)
                for r in self.records(kind="run", limit=limit)]

    def summary(self) -> Optional[Dict[str, Any]]:
        """The compaction aggregate, if any evictions have happened."""
        recs = self.records(kind="summary")
        return recs[-1] if recs else None

    # -- compaction ----------------------------------------------------------
    def compact(self) -> int:
        """Fold all but the newest ``max_records`` records into the
        aggregate summary; returns the number of records evicted."""
        with self._lock:
            return self._compact_locked()

    def _compact_locked(self) -> int:
        recs = self.records()
        keep_from = max(0, len(recs) - self.max_records)
        evicted, kept = recs[:keep_from], recs[keep_from:]
        if not evicted:
            self._count = len(recs)
            return 0
        # fold evicted runs (and any prior summary) into one aggregate
        agg = {"v": TELEMETRY_SCHEMA_VERSION, "kind": "summary",
               "runs": 0, "ticks": 0, "wall_s_sum": 0.0,
               "shuffle_s_sum": 0.0, "io_s_sum": 0.0,
               "cache_hits": 0, "retraces": 0,
               "first_t": None, "last_t": None}
        for rec in evicted:
            k = rec.get("kind")
            if k == "summary":
                for key in ("runs", "ticks", "cache_hits", "retraces"):
                    agg[key] += int(rec.get(key, 0))
                for key in ("wall_s_sum", "shuffle_s_sum", "io_s_sum"):
                    agg[key] += float(rec.get(key, 0.0))
                if rec.get("first_t") is not None:
                    agg["first_t"] = rec["first_t"] if agg["first_t"] is None \
                        else min(agg["first_t"], rec["first_t"])
                if rec.get("last_t") is not None:
                    agg["last_t"] = rec["last_t"] if agg["last_t"] is None \
                        else max(agg["last_t"], rec["last_t"])
                continue
            t = rec.get("t")
            if t is not None:
                agg["first_t"] = t if agg["first_t"] is None \
                    else min(agg["first_t"], t)
                agg["last_t"] = t if agg["last_t"] is None \
                    else max(agg["last_t"], t)
            if k == "tick":
                agg["ticks"] += 1
                continue
            agg["runs"] += 1
            agg["wall_s_sum"] += float(rec.get("wall_s", 0.0))
            agg["shuffle_s_sum"] += float(rec.get("shuffle_s", 0.0))
            agg["io_s_sum"] += float(rec.get("io_s", 0.0))
            agg["cache_hits"] += 1 if rec.get("plan_cache_hit") else 0
            agg["retraces"] += int(rec.get("retraces", 0))
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(json.dumps(agg) + "\n")
            for rec in kept:
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        if self._f is not None:               # reopen: old handle points at
            self._f.close()                   # the unlinked inode
            self._f = None
        self._count = len(kept) + 1
        self.compactions += 1
        return len(evicted)

    # -- stats / metrics -----------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"records": self._count, "appends": self.appends,
                    "compactions": self.compactions,
                    "max_records": self.max_records, "path": self.path}

    # -- trace-context carrier (cross-process stitching) ---------------------
    def save_trace_context(self, ctx: TraceContext, name: str) -> str:
        """Persist a wire-format TraceContext under the telemetry dir so
        a later process can pick it up (``load_trace_context``)."""
        path = os.path.join(self.dir, f"context-{name}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(ctx.to_wire(), f)
        os.replace(tmp, path)
        return path

    def load_trace_context(self, name: str) -> Optional[TraceContext]:
        path = os.path.join(self.dir, f"context-{name}.json")
        try:
            with open(path) as f:
                return TraceContext.from_wire(json.load(f))
        except (OSError, ValueError, KeyError):
            return None

    # -- cluster metrics aggregation -----------------------------------------
    def write_node_metrics(self, registry: MetricsRegistry,
                           node: str) -> str:
        """Snapshot a registry to ``metrics-<node>.json`` (atomic)."""
        path = os.path.join(self.dir, f"metrics-{_safe(node)}.json")
        doc = {"node": node, "snapshot": registry.snapshot()}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    def node_metrics(self) -> Dict[str, Dict[str, Any]]:
        """All per-node snapshots: ``{node: snapshot}``."""
        out: Dict[str, Dict[str, Any]] = {}
        import glob as _glob
        for path in sorted(_glob.glob(
                os.path.join(self.dir, "metrics-*.json"))):
            try:
                with open(path) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if isinstance(doc, dict) and "snapshot" in doc:
                node = str(doc.get("node")
                           or os.path.basename(path)[len("metrics-"):-5])
                out[node] = doc["snapshot"]
        return out

    def cluster_metrics(self) -> Dict[str, Any]:
        """Merged view over every node snapshot: one metrics document
        with a ``node`` label added to every sample."""
        return merge_node_snapshots(self.node_metrics())

    def cluster_metrics_text(self) -> str:
        """The merged view as Prometheus text exposition."""
        return snapshot_prometheus_text(self.cluster_metrics())


def _safe(label: str) -> str:
    return "".join(c if (c.isalnum() or c in "-_.") else "_" for c in label)
