"""Exporters: spans → Chrome ``trace_event`` JSON (Perfetto-loadable).

The Chrome trace-event format (the `catapult` JSON spec) is the lingua
franca of timeline viewers: ``chrome://tracing``, Perfetto's web UI and
``speedscope`` all open it directly.  We emit:

* one ``M`` (metadata) event per thread naming it (``thread_name``), so
  the serving pool workers and the Autopilot's optimizer thread show up
  labeled instead of as bare ids;
* one ``X`` (complete) event per finished span — ``ts``/``dur`` in
  microseconds off the tracer's shared ``perf_counter`` clock, ``args``
  carrying the span annotations plus our span/parent ids;
* an ``s``/``f`` (flow start/finish) pair for every cross-thread handoff
  a span recorded via ``tracer.attach`` — Perfetto draws these as arrows
  from the submitting span to the worker span, which is how a serve's
  ticket execution and the Autopilot's ticks visually attach to their
  origin.

Timestamps are rebased so the earliest span starts at t=0: perf_counter
has an arbitrary epoch and viewers dislike 6-digit-second offsets.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from .tracer import Span, TRACER

__all__ = ["to_chrome_trace", "write_chrome_trace", "chrome_trace_json"]

#: process id stamped on every event — single-process system, constant
_PID = 1


def to_chrome_trace(spans: Optional[Iterable[Span]] = None,
                    metadata: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Convert finished spans (default: the global tracer's buffer) into
    a Chrome trace-event document (the ``traceEvents`` object form)."""
    if spans is None:
        spans = TRACER.finished()
    spans = [sp for sp in spans if sp.t1 is not None]
    events: List[Dict[str, Any]] = []

    t_base = min((sp.t0 for sp in spans), default=0.0)

    def us(t: float) -> float:
        return round((t - t_base) * 1e6, 3)

    threads: Dict[int, str] = {}
    for sp in spans:
        threads.setdefault(sp.tid, sp.thread_name)
        if sp.flow_from is not None:
            threads.setdefault(sp.flow_from.tid, sp.flow_from.thread_name)

    for tid, name in sorted(threads.items()):
        events.append({"ph": "M", "name": "thread_name", "pid": _PID,
                       "tid": tid, "args": {"name": name}})

    flow_n = 0
    for sp in sorted(spans, key=lambda s: s.t0):
        args = {str(k): _jsonable(v) for k, v in sp.args.items()}
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        args["trace_id"] = sp.trace_id
        events.append({"ph": "X", "name": sp.name, "cat": sp.cat or "span",
                       "pid": _PID, "tid": sp.tid,
                       "ts": us(sp.t0), "dur": round(sp.dur_s * 1e6, 3),
                       "args": args})
        if sp.flow_from is not None:
            # arrow: from the capture point on the submitting thread to
            # this span's start on the worker thread
            flow_n += 1
            ctx = sp.flow_from
            events.append({"ph": "s", "id": flow_n, "name": "handoff",
                           "cat": "flow", "pid": _PID, "tid": ctx.tid,
                           "ts": us(min(ctx.captured_at, sp.t0))})
            events.append({"ph": "f", "id": flow_n, "name": "handoff",
                           "cat": "flow", "pid": _PID, "tid": sp.tid,
                           "ts": us(sp.t0), "bp": "e"})

    doc: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"exporter": "repro.obs", "spans": len(spans),
                      "dropped": TRACER.dropped},
    }
    if metadata:
        doc["otherData"].update({str(k): _jsonable(v)
                                 for k, v in metadata.items()})
    return doc


def chrome_trace_json(spans: Optional[Iterable[Span]] = None,
                      metadata: Optional[Dict[str, Any]] = None) -> str:
    return json.dumps(to_chrome_trace(spans, metadata))


def write_chrome_trace(path: str,
                       spans: Optional[Iterable[Span]] = None,
                       metadata: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Write a Perfetto-loadable trace file; returns the document."""
    doc = to_chrome_trace(spans, metadata)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)
