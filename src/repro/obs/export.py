"""Exporters: spans → Chrome ``trace_event`` JSON (Perfetto-loadable).

The Chrome trace-event format (the `catapult` JSON spec) is the lingua
franca of timeline viewers: ``chrome://tracing``, Perfetto's web UI and
``speedscope`` all open it directly.  We emit:

* one ``M`` (metadata) event per thread naming it (``thread_name``), so
  the serving pool workers and the Autopilot's optimizer thread show up
  labeled instead of as bare ids;
* one ``X`` (complete) event per finished span — ``ts``/``dur`` in
  microseconds off the tracer's shared ``perf_counter`` clock, ``args``
  carrying the span annotations plus our span/parent ids;
* still-open spans as ``X`` events too, flagged ``"incomplete": true``
  with duration-so-far — a crashed process's last in-flight span (the
  rebalance it died inside) survives into the trace instead of
  vanishing;
* an ``s``/``f`` (flow start/finish) pair for every cross-thread handoff
  a span recorded via ``tracer.attach`` — Perfetto draws these as arrows
  from the submitting span to the worker span, which is how a serve's
  ticket execution and the Autopilot's ticks visually attach to their
  origin.

Timestamps are rebased so the earliest span starts at t=0: perf_counter
has an arbitrary epoch and viewers dislike 6-digit-second offsets.

Cross-process stitching (DESIGN §15): each process *spills* its spans to
``<dir>/trace-<label>.jsonl`` (:func:`spill_spans`) — a header line with
a (perf_counter, wall-clock) anchor pair followed by one JSON record per
span, open spans included.  :func:`merge_process_traces` rebases every
file onto the shared wall clock via its anchor, assigns each process its
own Chrome ``pid`` (with ``process_name`` metadata rows), and pairs
``s``/``f`` flow events across process boundaries wherever a span's
root was attached to a :class:`~repro.obs.tracer.TraceContext` that came
over the wire from another process — so the three ``cluster_smoke``
processes render as ONE causal trace.
"""

from __future__ import annotations

import glob
import json
import os
import time
import warnings
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from .tracer import Span, TRACER, Tracer

__all__ = ["to_chrome_trace", "write_chrome_trace", "chrome_trace_json",
           "spill_spans", "load_spill", "merge_process_traces",
           "write_merged_trace", "SPILL_VERSION"]

#: process id stamped on every event of a single-process export
_PID = 1

#: schema version of the per-process span spill files
SPILL_VERSION = 1


def to_chrome_trace(spans: Optional[Iterable[Span]] = None,
                    metadata: Optional[Dict[str, Any]] = None,
                    include_open: bool = True) -> Dict[str, Any]:
    """Convert spans (default: the global tracer's buffer plus any
    still-open spans) into a Chrome trace-event document (the
    ``traceEvents`` object form).  Open spans export as ``X`` events
    flagged ``"incomplete": true`` with duration-so-far."""
    if spans is None:
        spans = TRACER.finished()
        if include_open:
            spans = spans + TRACER.open()
        now = time.perf_counter()
    else:
        spans = list(spans)
        # deterministic "now" for explicit span lists: the latest known
        # timestamp, so open-span durations don't depend on export time
        now = max((sp.t1 if sp.t1 is not None else sp.t0 for sp in spans),
                  default=0.0)
    if not include_open:
        spans = [sp for sp in spans if sp.t1 is not None]
    events: List[Dict[str, Any]] = []

    t_base = min((sp.t0 for sp in spans), default=0.0)

    def us(t: float) -> float:
        return round((t - t_base) * 1e6, 3)

    threads: Dict[int, str] = {}
    for sp in spans:
        threads.setdefault(sp.tid, sp.thread_name)
        if sp.flow_from is not None:
            threads.setdefault(sp.flow_from.tid, sp.flow_from.thread_name)

    for tid, name in sorted(threads.items()):
        events.append({"ph": "M", "name": "thread_name", "pid": _PID,
                       "tid": tid, "args": {"name": name}})

    flow_n = 0
    incomplete = 0
    for sp in sorted(spans, key=lambda s: s.t0):
        args = {str(k): _jsonable(v) for k, v in sp.args.items()}
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        args["trace_id"] = sp.trace_id
        if sp.t1 is None:
            incomplete += 1
            args["incomplete"] = True
            dur = max(now - sp.t0, 0.0)
        else:
            dur = sp.dur_s
        events.append({"ph": "X", "name": sp.name, "cat": sp.cat or "span",
                       "pid": _PID, "tid": sp.tid,
                       "ts": us(sp.t0), "dur": round(dur * 1e6, 3),
                       "args": args})
        if sp.flow_from is not None:
            # arrow: from the capture point on the submitting thread to
            # this span's start on the worker thread
            flow_n += 1
            ctx = sp.flow_from
            events.append({"ph": "s", "id": flow_n, "name": "handoff",
                           "cat": "flow", "pid": _PID, "tid": ctx.tid,
                           "ts": us(min(ctx.captured_at, sp.t0))})
            events.append({"ph": "f", "id": flow_n, "name": "handoff",
                           "cat": "flow", "pid": _PID, "tid": sp.tid,
                           "ts": us(sp.t0), "bp": "e"})

    doc: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"exporter": "repro.obs", "spans": len(spans),
                      "incomplete": incomplete, "dropped": TRACER.dropped},
    }
    if metadata:
        doc["otherData"].update({str(k): _jsonable(v)
                                 for k, v in metadata.items()})
    return doc


def chrome_trace_json(spans: Optional[Iterable[Span]] = None,
                      metadata: Optional[Dict[str, Any]] = None) -> str:
    return json.dumps(to_chrome_trace(spans, metadata))


def write_chrome_trace(path: str,
                       spans: Optional[Iterable[Span]] = None,
                       metadata: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Write a Perfetto-loadable trace file; returns the document."""
    doc = to_chrome_trace(spans, metadata)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


# ---------------------------------------------------------------------------
# per-process span spill + cross-process merge (DESIGN §15)
# ---------------------------------------------------------------------------

def _safe_label(label: str) -> str:
    return "".join(c if (c.isalnum() or c in "-_.") else "_" for c in label)


def _span_record(sp: Span) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "kind": "span", "name": sp.name, "cat": sp.cat,
        "span_id": sp.span_id, "parent_id": sp.parent_id,
        "trace_id": sp.trace_id, "tid": sp.tid,
        "thread_name": sp.thread_name, "t0": sp.t0, "t1": sp.t1,
        "args": {str(k): _jsonable(v) for k, v in sp.args.items()},
    }
    if sp.flow_from is not None:
        flow = sp.flow_from.to_wire()
        # keep the local perf-clock capture stamp too: intra-process
        # flows in the merged doc rebase it like any other timestamp
        flow["captured_at"] = sp.flow_from.captured_at
        rec["flow"] = flow
    return rec


def spill_spans(dir_path: str, label: Optional[str] = None,
                tracer: Optional[Tracer] = None,
                include_open: bool = True) -> str:
    """Write this process's spans to ``<dir>/trace-<label>.jsonl``.

    Line 1 is a header carrying the (perf_counter, wall-clock) anchor
    pair the merge step needs to rebase this process onto the shared
    wall clock; every following line is one span record.  Open spans are
    included by default (flagged by ``"t1": null``) — calling this from
    a crash path preserves the span the process died inside.
    """
    tr = tracer if tracer is not None else TRACER
    label = label or tr.process
    os.makedirs(dir_path, exist_ok=True)
    path = os.path.join(dir_path, f"trace-{_safe_label(label)}.jsonl")
    header = dict(tr.anchor(), kind="header", version=SPILL_VERSION,
                  label=label, mode=tr.mode, dropped=tr.dropped)
    spans = tr.finished()
    if include_open:
        spans = spans + tr.open()
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(json.dumps(header) + "\n")
        for sp in spans:
            f.write(json.dumps(_span_record(sp)) + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_spill(path: str) -> Optional[Dict[str, Any]]:
    """Parse one spill file → ``{"header": ..., "spans": [...]}``.

    Tolerant loader (same contract as decisions.log): torn trailing
    lines are ignored, a file whose header claims a *newer* spill
    version is skipped with a warning (returns None).
    """
    header: Optional[Dict[str, Any]] = None
    spans: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue                      # torn tail — ignore
            if not isinstance(rec, dict):
                continue
            if rec.get("kind") == "header":
                if int(rec.get("version", 1)) > SPILL_VERSION:
                    warnings.warn(
                        f"span spill {path} has version {rec.get('version')} "
                        f"> supported {SPILL_VERSION}; skipping file",
                        stacklevel=2)
                    return None
                header = rec
            elif rec.get("kind") == "span":
                spans.append(rec)
    if header is None:
        return None
    return {"header": header, "spans": spans}


def merge_process_traces(src: Union[str, Sequence[str]],
                         metadata: Optional[Dict[str, Any]] = None
                         ) -> Dict[str, Any]:
    """Stitch per-process spill files into ONE Chrome trace document.

    ``src`` is either a directory (every ``trace-*.jsonl`` inside) or an
    explicit list of spill paths.  Each file's anchor pair maps its
    process-local ``perf_counter`` timeline onto the shared wall clock;
    each process gets its own Chrome ``pid`` plus a ``process_name``
    metadata row, and every span whose root was attached to a wire-borne
    :class:`TraceContext` from another process gets a cross-process
    ``s``/``f`` flow pair back to the originating span's timeline.
    """
    if isinstance(src, str):
        paths = sorted(glob.glob(os.path.join(src, "trace-*.jsonl")))
    else:
        paths = list(src)
    files: List[Dict[str, Any]] = []
    skipped = 0
    for p in paths:
        loaded = load_spill(p)
        if loaded is None:
            skipped += 1
            continue
        h = loaded["header"]
        proc = str(h.get("process") or h.get("label")
                   or os.path.splitext(os.path.basename(p))[0])
        files.append({"process": proc, "header": h,
                      "spans": loaded["spans"]})

    # one Chrome pid per process, stable order
    pid_of = {f["process"]: i + 1 for i, f in enumerate(
        sorted(files, key=lambda f: f["process"]))}

    # rebase: unix_t = anchor_unix + (t - anchor_perf), per process
    def rebase_fn(h):
        a_perf = float(h.get("anchor_perf", 0.0))
        a_unix = float(h.get("anchor_unix", 0.0))
        return lambda t: a_unix + (float(t) - a_perf)

    starts: List[float] = []
    for f in files:
        rb = rebase_fn(f["header"])
        f["rebase"] = rb
        starts.extend(rb(rec["t0"]) for rec in f["spans"])
    t_base = min(starts, default=0.0)

    def us(t_unix: float) -> float:
        return round((t_unix - t_base) * 1e6, 3)

    events: List[Dict[str, Any]] = []
    for f in files:
        pid = pid_of[f["process"]]
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": f["process"]}})
        threads: Dict[int, str] = {}
        for rec in f["spans"]:
            threads.setdefault(int(rec["tid"]), str(rec["thread_name"]))
        for tid, name in sorted(threads.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})

    flow_n = 0
    n_spans = 0
    n_incomplete = 0
    n_cross = 0
    dropped = 0
    for f in files:
        pid = pid_of[f["process"]]
        proc = f["process"]
        rb = f["rebase"]
        dropped += int(f["header"].get("dropped", 0))
        # an open span's duration-so-far runs to the spill moment — the
        # anchor is stamped at spill time, so that IS anchor_unix
        spill_unix = float(f["header"].get("anchor_unix", 0.0))
        for rec in sorted(f["spans"], key=lambda r: r["t0"]):
            n_spans += 1
            flow = rec.get("flow")
            t0 = rb(rec["t0"])
            args = dict(rec.get("args") or {})
            args["span_id"] = rec["span_id"]
            if rec.get("parent_id") is not None:
                args["parent_id"] = rec["parent_id"]
            args["trace_id"] = rec["trace_id"]
            args["process"] = proc
            # process-qualified ids: span ids are per-process counters,
            # so only the (process, id) pair is unique in a merged doc
            args["span_uid"] = f"{proc}/{rec['span_id']}"
            if flow is not None:
                origin = str(flow.get("process") or proc)
                args["parent_uid"] = f"{origin}/{flow['span_id']}"
            elif rec.get("parent_id") is not None:
                args["parent_uid"] = f"{proc}/{rec['parent_id']}"
            if rec.get("t1") is None:
                n_incomplete += 1
                args["incomplete"] = True
                dur = max(spill_unix - t0, 0.0)
            else:
                dur = rb(rec["t1"]) - t0
            events.append({"ph": "X", "name": rec["name"],
                           "cat": rec.get("cat") or "span",
                           "pid": pid, "tid": int(rec["tid"]),
                           "ts": us(t0), "dur": round(dur * 1e6, 3),
                           "args": args})
            if flow is not None:
                origin = str(flow.get("process") or proc)
                origin_pid = pid_of.get(origin, pid)
                cross = origin != proc
                if cross:
                    n_cross += 1
                    # cross-process: only the wall-clock stamp is valid
                    ts_s = float(flow.get("captured_unix") or 0.0) or t0
                else:
                    cap = flow.get("captured_at")
                    ts_s = rb(cap) if cap else t0
                flow_n += 1
                events.append({"ph": "s", "id": flow_n,
                               "name": "xproc" if cross else "handoff",
                               "cat": "flow", "pid": origin_pid,
                               "tid": int(flow.get("tid", 0)),
                               "ts": us(min(ts_s, t0))})
                events.append({"ph": "f", "id": flow_n,
                               "name": "xproc" if cross else "handoff",
                               "cat": "flow", "pid": pid,
                               "tid": int(rec["tid"]),
                               "ts": us(t0), "bp": "e"})

    doc: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"exporter": "repro.obs.merge",
                      "processes": {p: pid for p, pid in
                                    sorted(pid_of.items())},
                      "spans": n_spans, "incomplete": n_incomplete,
                      "flows": flow_n, "cross_process_flows": n_cross,
                      "skipped_files": skipped, "dropped": dropped},
    }
    if metadata:
        doc["otherData"].update({str(k): _jsonable(v)
                                 for k, v in metadata.items()})
    return doc


def write_merged_trace(path: str, src: Union[str, Sequence[str]],
                       metadata: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Merge spill files and write the stitched trace; returns the doc."""
    doc = merge_process_traces(src, metadata)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)
