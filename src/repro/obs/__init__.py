"""Unified observability layer: span tracing, metrics, exporters.

See DESIGN.md §13.  Three pieces:

* :mod:`repro.obs.tracer` — low-overhead span tracer (off by default);
* :mod:`repro.obs.metrics` — one :class:`MetricsRegistry` for counters,
  gauges and fixed-bucket histograms, JSON + Prometheus exporters;
* :mod:`repro.obs.export` — Chrome ``trace_event`` JSON (Perfetto).
"""

from .tracer import (TRACER, Span, TraceContext, Tracer, clear_spans,
                     configure, disable, enable, finished_spans, span,
                     tracing_mode)
from .metrics import (DEFAULT_BUCKETS, METRICS_SCHEMA_VERSION, REGISTRY,
                      Counter, Gauge, Histogram, MetricsRegistry,
                      validate_snapshot)
from .export import chrome_trace_json, to_chrome_trace, write_chrome_trace

__all__ = [
    "TRACER", "Span", "TraceContext", "Tracer", "span", "configure",
    "enable", "disable", "tracing_mode", "finished_spans", "clear_spans",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "METRICS_SCHEMA_VERSION", "DEFAULT_BUCKETS", "validate_snapshot",
    "to_chrome_trace", "chrome_trace_json", "write_chrome_trace",
]
