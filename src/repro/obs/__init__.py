"""Unified observability layer: span tracing, metrics, exporters,
durable telemetry and the regression watchdog.

See DESIGN.md §13 (single-process) and §15 (cluster-wide).  Pieces:

* :mod:`repro.obs.tracer` — low-overhead span tracer (off by default),
  with wire-serializable :class:`TraceContext` for cross-process links;
* :mod:`repro.obs.metrics` — one :class:`MetricsRegistry` for counters,
  gauges and fixed-bucket histograms, JSON + Prometheus exporters, plus
  the node-labeled cluster merge and a strict text parser;
* :mod:`repro.obs.export` — Chrome ``trace_event`` JSON (Perfetto),
  per-process span spill and the cross-process trace merge;
* :mod:`repro.obs.telemetry` — bounded durable per-run history under
  the store root (:class:`TelemetryStore` / :class:`RunProfile`);
* :mod:`repro.obs.watchdog` — :class:`RegressionDetector` comparing
  rolling telemetry windows to a recorded baseline.
"""

from .tracer import (TRACER, TRACE_ENV_VAR, Span, TraceContext, Tracer,
                     clear_spans, configure, disable, enable,
                     finished_spans, open_spans, span, tracing_mode)
from .metrics import (DEFAULT_BUCKETS, METRICS_SCHEMA_VERSION, REGISTRY,
                      Counter, Gauge, Histogram, MetricsRegistry,
                      merge_node_snapshots, parse_prometheus_text,
                      snapshot_prometheus_text, validate_snapshot)
from .export import (chrome_trace_json, load_spill, merge_process_traces,
                     spill_spans, to_chrome_trace, write_chrome_trace,
                     write_merged_trace)
from .telemetry import TELEMETRY_SCHEMA_VERSION, RunProfile, TelemetryStore
from .watchdog import WATCHDOG_SERIES, RegressionDetector, WatchdogSignal

__all__ = [
    "TRACER", "TRACE_ENV_VAR", "Span", "TraceContext", "Tracer", "span",
    "configure", "enable", "disable", "tracing_mode", "finished_spans",
    "open_spans", "clear_spans",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "METRICS_SCHEMA_VERSION", "DEFAULT_BUCKETS", "validate_snapshot",
    "merge_node_snapshots", "snapshot_prometheus_text",
    "parse_prometheus_text",
    "to_chrome_trace", "chrome_trace_json", "write_chrome_trace",
    "spill_spans", "load_spill", "merge_process_traces",
    "write_merged_trace",
    "TelemetryStore", "RunProfile", "TELEMETRY_SCHEMA_VERSION",
    "RegressionDetector", "WatchdogSignal", "WATCHDOG_SERIES",
]
