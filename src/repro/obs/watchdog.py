"""Regression watchdog (DESIGN §15): rolling telemetry windows vs a
recorded baseline.

A :class:`RegressionDetector` reads the headline series out of the
durable :class:`~repro.obs.telemetry.TelemetryStore` — run wall p50,
retraces per run, padding waste ratio — plus the serving tier's
coalesce rate from the live :class:`MetricsRegistry`, and compares a
rolling window of them against a baseline recorded with
:meth:`record_baseline` (persisted as ``telemetry/baseline.json``, so
the comparison survives restarts like everything else here).

When a series regresses beyond ``tolerance`` it emits a
``perf_regression`` signal shaped exactly like
:class:`~repro.cluster.control.ClusterSignal` — same ``kind/node/step/
detail`` fields, same drain-once :meth:`signals` protocol — so the
Autopilot's tick consumes it through the very signal path ClusterHealth
uses and logs an explained why-record per alert.  Signals are deduped:
a series alerts once per excursion and re-arms only after it recovers
below the threshold, so a sustained regression is one alert, not one
per tick.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .telemetry import TelemetryStore

__all__ = ["RegressionDetector", "WatchdogSignal", "WATCHDOG_SERIES"]

#: headline series → True when a larger value is worse (run wall,
#: retraces, padding waste) and False when a *smaller* value is worse
#: (coalesce rate: fewer coalesced serves per completed serve means the
#: serving tier stopped deduplicating identical requests)
WATCHDOG_SERIES: Dict[str, bool] = {
    "run_wall_p50_s": True,
    "retraces_per_run": True,
    "padding_waste_ratio": True,
    "coalesce_rate": False,
}


@dataclass
class WatchdogSignal:
    """Duck-compatible with ``repro.cluster.control.ClusterSignal`` —
    the Autopilot prices both through one code path."""
    kind: str
    node: str                     # the regressing series name
    step: int = 0
    detail: Dict[str, Any] = field(default_factory=dict)


class RegressionDetector:
    """Compare rolling telemetry windows against a recorded baseline."""

    def __init__(self, telemetry: TelemetryStore, window: int = 32,
                 tolerance: float = 1.25, min_runs: int = 8,
                 registry: Any = None):
        if tolerance <= 1.0:
            raise ValueError("tolerance must be > 1.0")
        self.telemetry = telemetry
        self.window = int(window)
        self.tolerance = float(tolerance)
        self.min_runs = int(min_runs)
        self.registry = registry              # optional (coalesce rate)
        self.baseline_path = os.path.join(telemetry.dir, "baseline.json")
        self._signalled: set = set()          # series currently alerting
        self._pending: List[WatchdogSignal] = []
        self.raised_total = 0
        self.checks = 0

    # -- series extraction ---------------------------------------------------
    def window_stats(self) -> Dict[str, Optional[float]]:
        """Current values of every watched series over the newest
        ``window`` runs (None where undefined — e.g. no serving traffic)."""
        profiles = self.telemetry.run_profiles(limit=self.window)
        out: Dict[str, Optional[float]] = {k: None for k in WATCHDOG_SERIES}
        out["runs"] = float(len(profiles))
        if profiles:
            walls = sorted(p.wall_s for p in profiles)
            out["run_wall_p50_s"] = walls[len(walls) // 2]
            out["retraces_per_run"] = (
                sum(p.retraces for p in profiles) / len(profiles))
            valid = sum(p.valid_bytes for p in profiles)
            padded = sum(p.padded_bytes for p in profiles)
            if valid > 0:
                out["padding_waste_ratio"] = padded / valid
        out["coalesce_rate"] = self._coalesce_rate()
        return out

    def _coalesce_rate(self) -> Optional[float]:
        if self.registry is None:
            return None
        try:
            snap = self.registry.snapshot()["metrics"]
        except Exception:       # noqa: BLE001 — watchdog never takes
            return None         # down the loop it watches
        completed = sum(s.get("value", 0.0) for s in
                        snap.get("serving_completed",
                                 {}).get("samples", []))
        coalesced = sum(s.get("value", 0.0) for s in
                        snap.get("serving_coalesced",
                                 {}).get("samples", []))
        if completed <= 0:
            return None
        return coalesced / completed

    # -- baseline ------------------------------------------------------------
    def record_baseline(self) -> Dict[str, Any]:
        """Freeze the current window as the comparison baseline."""
        doc = {"version": 1, "recorded_unix_s": time.time(),
               "window": self.window, "tolerance": self.tolerance,
               "stats": self.window_stats()}
        tmp = self.baseline_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, self.baseline_path)
        return doc

    def baseline(self) -> Optional[Dict[str, Any]]:
        try:
            with open(self.baseline_path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return None
        if int(doc.get("version", 1)) > 1:
            return None                       # future schema — ignore
        return doc

    # -- checking ------------------------------------------------------------
    def check(self, step: int = 0) -> List[WatchdogSignal]:
        """Compare the current window to the baseline; queue one deduped
        ``perf_regression`` signal per newly-regressing series.  No-op
        (returns []) without a baseline or with too few runs."""
        self.checks += 1
        base = self.baseline()
        if base is None:
            return []
        cur = self.window_stats()
        if (cur.get("runs") or 0) < self.min_runs:
            return []
        tol = float(base.get("tolerance", self.tolerance))
        new: List[WatchdogSignal] = []
        for series, higher_is_worse in WATCHDOG_SERIES.items():
            b = base.get("stats", {}).get(series)
            c = cur.get(series)
            if b is None or c is None or b <= 0:
                continue
            ratio = c / b
            regressed = (ratio > tol) if higher_is_worse \
                else (ratio < 1.0 / tol)
            if regressed:
                if series in self._signalled:
                    continue                  # dedupe: one alert/excursion
                self._signalled.add(series)
                self.raised_total += 1
                sig = WatchdogSignal(
                    kind="perf_regression", node=series, step=step,
                    detail={"series": series, "observed": c, "baseline": b,
                            "ratio": ratio, "tolerance": tol,
                            "higher_is_worse": higher_is_worse,
                            "window_runs": cur.get("runs")})
                new.append(sig)
                self._pending.append(sig)
            else:
                self._signalled.discard(series)   # recovered — re-arm
        return new

    def signals(self) -> List[WatchdogSignal]:
        """Drain queued signals (the ClusterHealth protocol)."""
        out, self._pending = self._pending, []
        return out

    def stats(self) -> Dict[str, Any]:
        return {"checks": self.checks, "raised_total": self.raised_total,
                "alerting": sorted(self._signalled),
                "has_baseline": os.path.exists(self.baseline_path),
                "window": self.window, "tolerance": self.tolerance}
