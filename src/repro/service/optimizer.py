"""The Autopilot optimizer loop — "decide + apply" (DESIGN §8).

:class:`StorageOptimizer` closes the paper's loop online: per ``tick()`` it
walks every stored dataset, enumerates candidate layouts from the observed
history (Alg. 1+2 over each consumer IR in the skeleton graph), lets a
selector policy — greedy Eq. 2 or the DRL agent, both behind the same
``select(feats, groups, dataset_bytes, state)`` interface — pick the
preferred layout, prices it with the :class:`~repro.service.cost_model.
WhatIfCostModel`, and when the modeled benefit clears the hysteresis
threshold applies the :class:`~repro.core.advisor.PartitioningDecision`
through ``PartitionStore.repartition(swap=True)`` — the device-to-device
fast path when the store is device-backed — publishing a new generation
with one atomic pointer flip.

``tick()`` is the deterministic unit (tests, drift scenarios drive it
directly); ``start(period_s)`` runs the same tick on a daemon thread for a
live service.  Flip-flop guards: the hysteresis factor, a per-dataset
cooldown after each applied decision, and a minimum observed-run count.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.advisor import (GreedySelector, PartitioningDecision,
                            apply_decision)
from ..obs.tracer import TRACER as _TRACER, span as _span
from ..core.features import build_state, candidate_features
from ..core.history import HistoryStore
from ..core.partitioner import (SaltedPartitioner, dedupe,
                                enumerate_candidates)
from ..data.capacity import plan_capacity_map
from ..data.skew import HeavyHitterSketch
from .cost_model import LayoutScore, WhatIfCostModel
from .observer import Observer

#: in-memory why-record ring bound — enough to audit a long soak without
#: letting a permanently-attached autopilot grow without bound
WHY_RECORDS_CAP = 512


@dataclass
class AutopilotConfig:
    hysteresis: float = 1.5        # benefit must exceed cost × this factor
    window_s: float = float("inf")  # recency window for run-rate estimation
    horizon_windows: float = 4.0   # future windows a layout keeps paying off
    min_runs: float = 2.0          # observed runs before acting on a dataset
    cooldown_ticks: int = 1        # ticks to skip a dataset after a swap
    max_candidates: int = 12       # state-vector rows (advisor action space)
    max_history_records: Optional[int] = None   # auto-compact bound
    datasets: Optional[Tuple[str, ...]] = None  # allowlist (None = all)
    # -- skew actions (DESIGN §12) -------------------------------------------
    # None → follow the store (on iff store.adaptive_capacity); True/False
    # force.  Salting triggers when the dataset's fill skew reaches
    # skew_threshold AND the observed hottest-key share (heavy-hitter
    # sketch in the candidate stats) reaches hot_key_fraction.
    skew_actions: Optional[bool] = None
    hot_key_fraction: float = 0.25
    skew_threshold: float = 2.0
    salt_factor: int = 4
    # hottest-key share below which a salted layout is unwound (the split
    # stops paying for its lost elisions once the key cools).  None →
    # hot_key_fraction / 2: a deliberate gap between the salt and unsalt
    # thresholds so a key oscillating around hot_key_fraction never
    # flip-flops the layout.
    unsalt_hot_key_fraction: Optional[float] = None
    # -- cluster actions (DESIGN §14) ----------------------------------------
    # None → follow the store (on iff the store is cluster-backed);
    # True/False force.  When on, the tick drains the store's
    # ClusterHealth signals (lost nodes, stragglers) and answers each with
    # a priced rebalance decision.
    cluster_actions: Optional[bool] = None


@dataclass
class AppliedDecision:
    """One autonomous layout action: the advisor decision (None for a
    rebucket — no candidate changes), its what-if score, and what actually
    happened when it was applied."""
    dataset: str                   # "*" for a store-wide rebalance
    decision: Optional[PartitioningDecision]
    score: LayoutScore
    generation: int                # generation published by the swap
                                   # (directory epoch for a rebalance)
    moved_bytes: int
    repartition_wall_s: float
    path: str                      # "d2d" | "host" | "rebucket" | "rebalance"
    kind: str = "repartition"      # "repartition" | "salt" | "unsalt" |
                                   # "rebucket" | "rebalance"


@dataclass
class TickReport:
    tick: int
    now: float
    considered: List[Tuple[str, str, LayoutScore]] = field(
        default_factory=list)      # (dataset, candidate sig, score)
    applied: List[AppliedDecision] = field(default_factory=list)
    compacted: int = 0
    why: List[Dict[str, Any]] = field(default_factory=list)


class StorageOptimizer:
    """The decide→apply loop over one store + one history."""

    def __init__(self, store, history: HistoryStore, *,
                 cost_model: Optional[WhatIfCostModel] = None,
                 selector=None,
                 config: Optional[AutopilotConfig] = None,
                 mesh=None,
                 clock: Callable[[], float] = time.time):
        self.store = store
        self.history = history
        self.cost_model = cost_model or WhatIfCostModel()
        self.selector = selector or GreedySelector()
        self.cfg = config or AutopilotConfig()
        self.mesh = mesh
        self.clock = clock
        self.reports: List[TickReport] = []
        self.why_records: List[Dict[str, Any]] = []
        self._cooldown: Dict[str, int] = {}
        self._tick_no = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.last_error: Optional[BaseException] = None

    # -- candidate enumeration over the observed consumer IRs ----------------
    def _enumerate(self, dataset: str, groups):
        cands, cand_groups, rel_groups = [], {}, []
        for sig in sorted(groups):
            ir = self.history.ir_of(sig)
            if ir is None or ir.find_scanner(dataset) is None:
                continue
            rel_groups.append(groups[sig])
            for c in enumerate_candidates(ir, dataset):
                cands.append(c)
                cand_groups.setdefault(c.signature(), []).append(groups[sig])
        return dedupe(cands), cand_groups, rel_groups

    # -- skew actions: hot-key salting + capacity rebucketing (DESIGN §12) ---
    def _skew_enabled(self) -> bool:
        if self.cfg.skew_actions is not None:
            return bool(self.cfg.skew_actions)
        return bool(getattr(self.store, "adaptive_capacity", False))

    # -- cluster actions: health signals → rebalance decisions (DESIGN §14) --
    def _cluster_enabled(self) -> bool:
        if self.cfg.cluster_actions is not None:
            return bool(self.cfg.cluster_actions)
        return bool(getattr(self.store, "is_cluster", False))

    def _window_run_rate(self, now: float) -> float:
        """Weight-aware observed runs inside the recency window, across
        every consumer — the rate a store-wide degradation is paid at."""
        return sum(r.weight for r in self.history.records
                   if r.timestamp >= now - self.cfg.window_s)

    def _consider_cluster(self, now: float, report: TickReport):
        """Drain the store's ClusterHealth signals and answer each with a
        priced rebalance consideration.  At most one rebalance queues per
        tick (applying one bumps the placement epoch, which would stale
        any plan built alongside it); every signal still gets its own
        why-record.  Returns the queued ``("rebalance", "*", plan,
        score)`` or None."""
        health = getattr(self.store, "health", None)
        if health is None:
            return None
        queued = None
        for sig in health.signals():
            directory = self.store.directory
            node, nodes = sig.node, directory.nodes
            survivors = [n for n in nodes if n != node]
            candidate = f"remove:{node}"
            gates = [
                self._gate("node_in_membership", node in nodes, node=node),
                self._gate("surviving_nodes", len(survivors) >= 1,
                           observed=len(survivors), required=1),
                self._gate("single_rebalance_per_tick", queued is None),
            ]
            if not all(g["passed"] for g in gates):
                self._why(report, "*", f"rebalance:{sig.kind}", candidate,
                          None, gates, False)
                continue
            plan = self.store.plan_rebalance(
                remove_nodes=(node,), reason=f"{sig.kind}:{node}")
            cost_s = self.cost_model.rebalance_seconds(plan.est_bytes_moved)
            runs = self._window_run_rate(now)
            if sig.kind == "node_lost":
                # until the displaced partitions re-home, every run reads
                # them degraded off replicas and the store sits one more
                # failure from data loss — each windowed run is priced as
                # re-paying the displaced bytes' transfer
                benefit_s = max(runs, 1.0) * cost_s
            else:   # straggler: runs keep paying the node's excess latency
                benefit_s = runs * float(sig.detail.get("excess_s", 0.0))
            score = LayoutScore(
                dataset="*", candidate_signature=candidate,
                benefit_s=benefit_s, repartition_s=0.0,
                runs_in_window=runs, shuffles_delta=0.0, io_s=cost_s)
            report.considered.append(("*", candidate, score))
            gates.append(self._gate(
                "mesh_replan", not plan.mesh_error,
                error=plan.mesh_error,
                mesh=str(plan.mesh.shape) if plan.mesh else ""))
            if sig.kind == "node_lost":
                # replication must be restored — a lost node is priced for
                # the record but never benefit-gated
                gates.append(self._gate("replication_at_risk", True,
                                        missed=sig.detail.get("missed", 0.0)))
            else:
                gates.append(self._gate(
                    "worth_it", score.worth_it(self.cfg.hysteresis,
                                               self.cfg.horizon_windows)))
            accepted = all(g["passed"] for g in gates)
            self._why(report, "*", f"rebalance:{sig.kind}", candidate, score,
                      gates, accepted)
            if accepted:
                queued = ("rebalance", "*", plan, score)
        return queued

    def _apply_rebalance(self, plan, score: LayoutScore, report: TickReport,
                         now: float) -> None:
        """Apply a queued rebalance plan: stream the minimal move set and
        commit the new placement epoch (one atomic pointer flip per
        dataset, then the EPOCH pointer)."""
        with _span("autopilot.apply", "autopilot", dataset="*",
                   kind="rebalance") as asp:
            try:
                res = self.store.rebalance(plan=plan)
            except ValueError as e:    # plan went stale under our feet
                asp.set(skipped=str(e))
                return
            streamed = res.bytes_moved + res.replica_bytes
            if streamed > 0 and res.wall_s > 0:
                self.cost_model.observe_io(streamed, res.wall_s)
            applied = AppliedDecision(
                dataset="*", decision=None, score=score,
                generation=res.epoch, moved_bytes=res.bytes_moved,
                repartition_wall_s=res.wall_s, path="rebalance",
                kind="rebalance")
            asp.set(epoch=res.epoch, moved_bytes=int(res.bytes_moved),
                    partitions_moved=int(res.partitions_moved),
                    bytes_linked=int(res.bytes_linked))
            report.applied.append(applied)
            self._catalog_log(applied, now)

    # -- decision explainability (DESIGN §13) --------------------------------
    @staticmethod
    def _gate(name: str, passed: bool, **detail) -> Dict[str, Any]:
        g: Dict[str, Any] = {"gate": name, "passed": bool(passed)}
        for k, v in detail.items():
            g[k] = float(v) if isinstance(v, (int, float)) else v
        return g

    def _why(self, report: TickReport, dataset: str, action: str,
             candidate: str, score: Optional[LayoutScore],
             gates: List[Dict[str, Any]], accepted: bool) -> None:
        """One structured why-record: the candidate's priced score (full
        gate math) plus every gate's verdict, whether it accepted or
        rejected the candidate.  Records accumulate on the tick's report;
        :meth:`tick` batches them into ``decisions.log`` and the bounded
        in-memory ring behind :meth:`explain`."""
        report.why.append({
            "kind": "why", "tick": self._tick_no, "now": float(report.now),
            "dataset": dataset, "action": action, "candidate": candidate,
            "accepted": bool(accepted),
            "score": (score.explain(self.cfg.hysteresis,
                                    self.cfg.horizon_windows)
                      if score is not None else None),
            "gates": gates,
        })

    def explain(self, limit: Optional[int] = None) -> List[Dict[str, Any]]:
        """Recent why-records (oldest first, bounded in memory at
        :data:`WHY_RECORDS_CAP`)."""
        recs = list(self.why_records)
        return recs[-limit:] if limit else recs

    def _observed_hot_fraction(self, cands, now: float) -> float:
        """Largest heavy-hitter share the Observer's per-candidate stats
        pass measured for any of this dataset's candidates inside the
        recency window — a lower bound (Misra-Gries), so acting on it
        never over-triggers a split."""
        sigs = {c.signature() for c in cands}
        best = 0.0
        for rec in self.history.records:
            if rec.timestamp < now - self.cfg.window_s:
                continue
            for sig, st in rec.candidate_stats.items():
                if sig in sigs:
                    best = max(best, float(st.get("max_key_fraction", 0.0)))
        return best

    def _consider_skew(self, name: str, ds, cands, groups, now: float,
                       report: TickReport):
        """Price the two skew actions for one dataset; return a queued
        ``(kind, name, decision, score)`` or None.  Salting is tried first
        (it changes which rows go where, fixing the imbalance at the
        source); rebucketing is the fallback that keeps the partitioner
        and only re-shapes per-partition capacity."""
        cur_sig = ds.partitioner.signature() if ds.partitioner else ""
        # -- hot-key splitting ------------------------------------------------
        base = next((c for c in cands if c.is_keyed and c.graph is not None),
                    None)
        if base is not None and "salt" not in cur_sig:
            skew = float(ds.skew())
            hot = self._observed_hot_fraction(cands, now)
            gates = [
                self._gate("skew_threshold",
                           skew >= self.cfg.skew_threshold,
                           observed=skew, required=self.cfg.skew_threshold),
                self._gate("hot_key_fraction",
                           hot >= self.cfg.hot_key_fraction,
                           observed=hot, required=self.cfg.hot_key_fraction),
            ]
            if not all(g["passed"] for g in gates):
                self._why(report, name, "salt", "", None, gates, False)
            else:
                # score with an empty-keyed preview: a salted signature
                # never matches Alg. 4, so its elision count (0) prices the
                # benefit the split gives up, against the padding bytes it
                # wins back
                preview = SaltedPartitioner(
                    graph=base.graph, strategy=base.strategy,
                    source_dataset=base.source_dataset, origin=base.origin,
                    hot_keys=(), salt_factor=self.cfg.salt_factor)
                score = self.cost_model.score(
                    name, float(ds.nbytes), ds.num_workers, preview,
                    ds.partitioner, self.history, now=now,
                    window_s=self.cfg.window_s, groups=groups,
                    durable=self.store.is_durable and self.store.autoflush,
                    source_spilled=self.store.is_durable
                    and self.store.is_spilled(name),
                    current_padded_bytes=float(ds.padded_bytes),
                    current_valid_bytes=float(ds.valid_bytes),
                    # salted counts are near-balanced; power-of-two rounding
                    # bounds the residual padding at 2×, 1.25× is the
                    # midpoint
                    candidate_padded_bytes=1.25 * float(ds.valid_bytes))
                report.considered.append((name, preview.signature(), score))
                gates.append(self._gate(
                    "min_runs", score.runs_in_window >= self.cfg.min_runs,
                    observed=score.runs_in_window,
                    required=self.cfg.min_runs))
                gates.append(self._gate(
                    "worth_it", score.worth_it(self.cfg.hysteresis,
                                               self.cfg.horizon_windows)))
                accepted = all(g["passed"] for g in gates)
                self._why(report, name, "salt", preview.signature(), score,
                          gates, accepted)
                if accepted:
                    decision = PartitioningDecision(
                        dataset=name, candidate=base, features=[],
                        consumers=[], action_index=-1, state=None,
                        elapsed_s=0.0)
                    return ("salt", name, decision, score)
        # -- hot-key cooling: unwind a salted layout --------------------------
        elif base is not None and "salt" in cur_sig:
            hot = self._observed_hot_fraction(cands, now)
            unsalt_thr = (self.cfg.unsalt_hot_key_fraction
                          if self.cfg.unsalt_hot_key_fraction is not None
                          else self.cfg.hot_key_fraction / 2.0)
            gates = [self._gate("hot_key_cooled", hot < unsalt_thr,
                                observed=hot, required=unsalt_thr)]
            if not all(g["passed"] for g in gates):
                self._why(report, name, "unsalt", "", None, gates, False)
            else:
                # the cooled key no longer needs the split; the plain keyed
                # layout matches Alg. 4 again, so its restored elisions are
                # the benefit side — no padding term (a cooled key fills
                # partitions evenly under either layout)
                score = self.cost_model.score(
                    name, float(ds.nbytes), ds.num_workers, base,
                    ds.partitioner, self.history, now=now,
                    window_s=self.cfg.window_s, groups=groups,
                    durable=self.store.is_durable and self.store.autoflush,
                    source_spilled=self.store.is_durable
                    and self.store.is_spilled(name))
                report.considered.append((name, base.signature(), score))
                gates.append(self._gate(
                    "min_runs", score.runs_in_window >= self.cfg.min_runs,
                    observed=score.runs_in_window,
                    required=self.cfg.min_runs))
                gates.append(self._gate(
                    "worth_it", score.worth_it(self.cfg.hysteresis,
                                               self.cfg.horizon_windows)))
                accepted = all(g["passed"] for g in gates)
                self._why(report, name, "unsalt", base.signature(), score,
                          gates, accepted)
                if accepted:
                    decision = PartitioningDecision(
                        dataset=name, candidate=base, features=[],
                        consumers=[], action_index=-1, state=None,
                        elapsed_s=0.0)
                    return ("unsalt", name, decision, score)
        # -- capacity rebucketing ---------------------------------------------
        if ds.partitioner is None:
            return None
        cmap = plan_capacity_map(
            ds.counts, threshold=getattr(self.store, "capacity_threshold",
                                         0.75))
        if cmap == ds.capacity_map or \
                (cmap is None and ds.capacity_map is None):
            return None
        slots = max(ds.total_slots, 1)
        per_slot = float(ds.padded_bytes) / slots
        new_slots = (cmap.total_slots if cmap is not None
                     else ds.num_workers * int(ds.counts.max()))
        score = self.cost_model.score(
            name, float(ds.nbytes), ds.num_workers, ds.partitioner,
            ds.partitioner, self.history, now=now,
            window_s=self.cfg.window_s, groups=groups,
            durable=self.store.is_durable and self.store.autoflush,
            source_spilled=False,   # rebucket reads the live generation
            current_padded_bytes=float(ds.padded_bytes),
            current_valid_bytes=float(ds.valid_bytes),
            candidate_padded_bytes=per_slot * new_slots,
            local=True)             # same partitioner: node-local rewrite
        report.considered.append((name, "rebucket", score))
        gates = [
            self._gate("min_runs",
                       score.runs_in_window >= self.cfg.min_runs,
                       observed=score.runs_in_window,
                       required=self.cfg.min_runs),
            self._gate("worth_it", score.worth_it(self.cfg.hysteresis,
                                                  self.cfg.horizon_windows)),
        ]
        accepted = all(g["passed"] for g in gates)
        self._why(report, name, "rebucket", "rebucket", score, gates,
                  accepted)
        if accepted:
            return ("rebucket", name, None, score)
        return None

    def _make_salted(self, name: str, base) -> Optional[SaltedPartitioner]:
        """Materialize the salt decision at apply time: sketch the live key
        column for its heavy hitters (the tick gate used the Observer's
        windowed stats; the actual keys may have drifted since)."""
        ds = self.store.read(name)
        keys = np.asarray(base.key_fn()(ds.gather())).reshape(-1)
        sk = HeavyHitterSketch(k=8).update(keys)
        hot = tuple(sorted(k for k, _ in
                           sk.heavy_hitters(self.cfg.hot_key_fraction)))
        if not hot:
            return None
        return SaltedPartitioner(
            graph=base.graph, strategy=base.strategy,
            source_dataset=base.source_dataset, origin=base.origin,
            hot_keys=hot, salt_factor=self.cfg.salt_factor)

    # -- one deterministic pass over the store -------------------------------
    def tick(self) -> TickReport:
        """Score every dataset against one calibration snapshot, then apply
        the decisions that cleared the gates (two-phase, so the order the
        store iterates in never skews a later dataset's pricing).

        The clock is read without advancing when it supports ``peek()``
        (LogicalClock): scoring a tick must not age the history it scores,
        or idle polling alone would push observed runs out of the recency
        window."""
        with _span("autopilot.tick", "autopilot") as tsp:
            return self._tick(tsp)

    def _tick(self, tsp) -> TickReport:
        peek = getattr(self.clock, "peek", None)
        now = peek() if peek is not None else self.clock()
        self._tick_no += 1
        report = TickReport(tick=self._tick_no, now=now)
        # (kind, dataset, decision-or-None, score)
        to_apply: List[Tuple[str, str,
                             Optional[PartitioningDecision], LayoutScore]] = []
        # one O(records²) skeleton build per tick, shared by every dataset's
        # enumeration and what-if score
        groups, _ = self.history.skeleton_graph()
        # watchdog phase (DESIGN §15): regression alerts from the durable
        # telemetry become explained why-records through the same path
        # ClusterHealth signals take
        self._consider_watchdog(report)
        # cluster phase first: a queued rebalance applies before any
        # per-dataset swap, so those swaps persist against the new placement
        if self._cluster_enabled():
            cluster = self._consider_cluster(now, report)
            if cluster is not None:
                to_apply.append(cluster)
        for name in sorted(self.store.datasets):
            if self.cfg.datasets is not None and name not in self.cfg.datasets:
                continue
            if self._cooldown.get(name, 0) > 0:
                self._cooldown[name] -= 1
                continue
            ds = self.store.read(name)
            cands, cand_groups, rel_groups = self._enumerate(name, groups)
            queued = False
            # a salted dataset under active skew management is owned by the
            # skew phase: unwinding the split must clear the hot_key_cooled
            # gate, or the generic phase would flip a still-hot key straight
            # back to the keyed layout it just split away from
            salted_now = ds.partitioner is not None and \
                "salt" in ds.partitioner.signature()
            if cands and not (salted_now and self._skew_enabled()):
                # policy pick (greedy Eq. 2 / DRL — one interface)
                t0 = time.perf_counter()
                feats = [candidate_features(c,
                                            cand_groups.get(c.signature(), []),
                                            self.history, now)
                         for c in cands]
                state = build_state(feats, float(ds.nbytes),
                                    self.cfg.max_candidates, now=now)
                idx = self.selector.select(feats, rel_groups,
                                           float(ds.nbytes), state)
                idx = max(0, min(int(idx), len(feats) - 1))
                cand = feats[idx].candidate
                decision = PartitioningDecision(
                    dataset=name, candidate=cand, features=feats,
                    consumers=[g.ir_signature for g in rel_groups],
                    action_index=idx, state=state,
                    elapsed_s=time.perf_counter() - t0)

                # what-if gate against the live layout; a durable store also
                # pays segment I/O (persist the new generation, rehydrate a
                # spilled source) — priced by the calibrated io throughput
                score = self.cost_model.score(
                    name, float(ds.nbytes), ds.num_workers, cand,
                    ds.partitioner, self.history, now=now,
                    window_s=self.cfg.window_s, groups=groups,
                    # only charge the persist when applying will actually
                    # pay it (autoflush); batched stores defer that cost
                    durable=self.store.is_durable and self.store.autoflush,
                    source_spilled=self.store.is_durable
                    and self.store.is_spilled(name))
                report.considered.append((name, cand.signature(), score))
                same = (ds.partitioner is not None and
                        ds.partitioner.signature() == cand.signature())
                gates = [
                    self._gate("not_current_layout", not same,
                               current=(ds.partitioner.signature()
                                        if ds.partitioner else "")),
                    self._gate("min_runs",
                               score.runs_in_window >= self.cfg.min_runs,
                               observed=score.runs_in_window,
                               required=self.cfg.min_runs),
                    self._gate("worth_it",
                               score.worth_it(self.cfg.hysteresis,
                                              self.cfg.horizon_windows)),
                ]
                accepted = all(g["passed"] for g in gates)
                self._why(report, name, "repartition", cand.signature(),
                          score, gates, accepted)
                if accepted:
                    to_apply.append(("repartition", name, decision, score))
                    queued = True
            # skew phase (DESIGN §12): when no layout change was queued,
            # consider hot-key salting and capacity rebucketing — actions
            # that fix padding waste rather than elide shuffles
            if not queued and self._skew_enabled():
                skew = self._consider_skew(name, ds, cands, groups, now,
                                           report)
                if skew is not None:
                    to_apply.append(skew)

        if report.why:
            # one bounded in-memory ring + one JSONL row per tick (the
            # records ride together so a busy tick costs one fsync).
            # Logged BEFORE the applies so the catalog reads
            # considered-then-applied and the newest row stays the latest
            # applied decision, as pre-§13 consumers of decisions() expect.
            self.why_records.extend(report.why)
            del self.why_records[:-WHY_RECORDS_CAP]
            if self.store.durable is not None:
                self.store.durable.log_decision({
                    "kind": "why", "tick": self._tick_no,
                    "now": float(now), "count": len(report.why),
                    "records": report.why})

        for kind, name, decision, score in to_apply:
            if kind == "rebalance":   # store-wide: no single dataset to read
                self._apply_rebalance(decision, score, report, now)
                continue
            # apply: materialize off to the side, atomically flip (swap)
            with _span("autopilot.apply", "autopilot", dataset=name,
                       kind=kind) as asp:
                ds_bytes = float(self.store.read(name).nbytes)
                io0 = self.store.io_snapshot()
                t1 = time.perf_counter()
                if kind in ("repartition", "unsalt"):
                    new, moved = apply_decision(self.store, decision,
                                                mesh=self.mesh)
                elif kind == "salt":
                    salted = self._make_salted(name, decision.candidate)
                    if salted is None:
                        asp.set(skipped="no_hot_key_at_apply")
                        continue   # sketch found no hot key at apply time
                    decision = PartitioningDecision(
                        dataset=name, candidate=salted,
                        features=decision.features,
                        consumers=decision.consumers, action_index=-1,
                        state=decision.state, elapsed_s=decision.elapsed_s)
                    new, moved = self.store.repartition(
                        self.store.read(name), salted, mesh=self.mesh,
                        swap=True)
                else:   # rebucket: same partitioner, node-local re-layout
                    new, moved = self.store.rebucket(name)
                wall = time.perf_counter() - t1
                # the wall includes any autoflush persist; attribute that
                # slice to the io calibration and only the remainder to the
                # shuffle, so score()'s repartition_s + io_s never
                # double-charges
                io_wall = self._feed_io_calibration(io0)
                if kind != "rebucket":   # rebucket moves 0 bytes — no sample
                    self.cost_model.observe_repartition(
                        ds_bytes, max(wall - io_wall, 0.0))
                self._cooldown[name] = self.cfg.cooldown_ticks
                path = "host"
                if self.store.write_log and \
                        self.store.write_log[-1].get("name") == name:
                    path = self.store.write_log[-1].get("path", "host")
                applied = AppliedDecision(
                    dataset=name, decision=decision, score=score,
                    generation=new.generation, moved_bytes=moved,
                    repartition_wall_s=wall, path=path, kind=kind)
                asp.set(generation=new.generation, moved_bytes=int(moved),
                        path=path)
                report.applied.append(applied)
                self._catalog_log(applied, now)
        if self.cfg.max_history_records is not None:
            report.compacted = self.history.compact(
                self.cfg.max_history_records)
        self._record_tick_telemetry(report, now)
        self.reports.append(report)
        tsp.set(tick=self._tick_no, considered=len(report.considered),
                applied=len(report.applied))
        return report

    def _consider_watchdog(self, report: TickReport) -> None:
        """Run the telemetry regression watchdog (DESIGN §15) and turn
        each deduped ``perf_regression`` signal into an explained
        why-record.  Alerts are observations, not actions — nothing
        queues for apply, but every alert leaves an audit trail in
        ``decisions.log`` with the observed/baseline/tolerance math."""
        wd = getattr(self.store, "watchdog", None)
        if wd is None:
            return
        try:
            wd.check(step=self._tick_no)
            sigs = wd.signals()
        except Exception:   # noqa: BLE001 — the watchdog must never take
            return          # down the optimizer loop it watches
        for sig in sigs:
            det = dict(sig.detail)
            gates = [self._gate(
                "tolerance_exceeded", True,
                series=str(det.get("series", sig.node)),
                observed=det.get("observed", 0.0),
                baseline=det.get("baseline", 0.0),
                ratio=det.get("ratio", 0.0),
                tolerance=det.get("tolerance", 0.0))]
            self._why(report, "*", f"watchdog:{sig.kind}", sig.node,
                      None, gates, True)

    def _record_tick_telemetry(self, report: TickReport,
                               now: float) -> None:
        """Append one per-tick snapshot to the durable telemetry so the
        decision cadence survives next to the run profiles it acted on."""
        tele = getattr(self.store, "telemetry", None)
        if tele is None:
            return
        try:
            tele.record_tick({
                "tick": self._tick_no, "now": float(now),
                "considered": len(report.considered),
                "applied": [{"dataset": a.dataset, "kind": a.kind,
                             "generation": int(a.generation),
                             "moved_bytes": int(a.moved_bytes)}
                            for a in report.applied],
                "why_count": len(report.why)})
        except OSError:      # advisory — never fail the tick
            pass

    # -- durable-store integration (DESIGN §10) ------------------------------
    def _feed_io_calibration(self, io_before) -> float:
        """Turn the segment I/O an applied decision just caused (persist of
        the swapped generation, rehydration of a spilled source) into an
        io-throughput sample for the what-if model.  Returns the I/O wall
        seconds so the caller can subtract them from the shuffle sample."""
        if not io_before:
            return 0.0
        io1 = self.store.io_snapshot()
        d_bytes = (io1["bytes_written"] - io_before["bytes_written"]
                   + io1["bytes_read"] - io_before["bytes_read"])
        d_s = (io1["write_s"] - io_before["write_s"]
               + io1["read_s"] - io_before["read_s"])
        if d_bytes > 0 and d_s > 0:
            self.cost_model.observe_io(d_bytes, d_s)
        return max(float(d_s), 0.0)

    def _catalog_log(self, applied: AppliedDecision, now: float) -> None:
        """Record an applied decision in the durable store's catalog
        (``decisions.log``), so a later process reopening the store can
        audit why its layouts look the way they do.  No-op when the store
        is memory-only."""
        if self.store.durable is None:
            return
        s = applied.score
        self.store.durable.log_decision({
            "tick": self._tick_no, "now": float(now),
            "dataset": applied.dataset,
            "kind": applied.kind,
            "candidate": (applied.decision.candidate.signature()
                          if applied.decision is not None else ""),
            "generation": applied.generation,
            "moved_bytes": int(applied.moved_bytes),
            "repartition_wall_s": float(applied.repartition_wall_s),
            "path": applied.path,
            "benefit_s": float(s.benefit_s),
            "repartition_s": float(s.repartition_s),
            "io_s": float(s.io_s),
            "runs_in_window": float(s.runs_in_window),
            "shuffles_delta": float(s.shuffles_delta),
        })

    # -- background service mode ---------------------------------------------
    def start(self, period_s: float = 1.0) -> None:
        """Run ``tick()`` on a daemon thread every ``period_s`` until
        :meth:`stop`.  Exceptions land in ``last_error`` (and stop the
        loop) rather than killing the host process."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("optimizer already running")
        self._stop.clear()
        # capture the starting thread's span context so background ticks
        # parent (via a flow arrow) to whatever started the service
        ctx = _TRACER.context()

        def _loop():
            with _TRACER.attach(ctx):
                while not self._stop.wait(period_s):
                    try:
                        self.tick()
                    except BaseException as e:  # noqa: BLE001 — report & halt
                        self.last_error = e
                        return

        self._thread = threading.Thread(
            target=_loop, name="lachesis-autopilot", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None


class Autopilot:
    """Facade wiring the whole subsystem to one execution surface:
    Observer (history + throughput calibration) + WhatIfCostModel +
    StorageOptimizer.

    Attaches to anything exposing ``.store`` and ``.add_run_hook`` — a
    :class:`~repro.api.Session` (``session.autopilot()`` is the idiomatic
    spelling) or the legacy Engine shim::

        sess = Session(store)
        ap = sess.autopilot(clock=LogicalClock())
        sess.run(workload)         # observed automatically
        ap.tick()                  # decide + apply + swap generations

    Every applied decision publishes a new layout generation, which by
    construction invalidates exactly the cached PhysicalPlans that scan
    the repartitioned dataset (their cache key pins the generation) — the
    session re-plans on its next run and picks up the elisions.
    """

    def __init__(self, session, *, clock: Optional[Callable[[], float]] = None,
                 config: Optional[AutopilotConfig] = None,
                 selector=None, history: Optional[HistoryStore] = None,
                 bench_path: Optional[str] = None, mesh=None):
        clock = clock or time.time
        self.history = history if history is not None else HistoryStore()
        self.cost_model = WhatIfCostModel(bench_path=bench_path)
        self.observer = Observer(
            self.history, clock=clock, cost_model=self.cost_model,
            max_records=(config.max_history_records if config else None))
        self.observer.attach(session)
        self.optimizer = StorageOptimizer(
            session.store, self.history, cost_model=self.cost_model,
            selector=selector, config=config, mesh=mesh, clock=clock)
        self.session = session
        self.engine = session          # pre-split alias

    def tick(self) -> TickReport:
        return self.optimizer.tick()

    def explain(self, limit: Optional[int] = None) -> List[Dict[str, Any]]:
        """Structured why-records for recent ticks (see
        :meth:`StorageOptimizer.explain`); the surface
        ``session.explain_decisions()`` reads."""
        return self.optimizer.explain(limit)

    def start(self, period_s: float = 1.0) -> None:
        self.optimizer.start(period_s)

    def stop(self, timeout: float = 10.0) -> None:
        self.optimizer.stop(timeout)
