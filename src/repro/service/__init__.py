# Autopilot: the online storage-optimizer service (DESIGN §8).
#   observer   — Session/Engine run hook → auto ExecutionRecords + calibration
#   cost_model — what-if layout scoring from measured shuffle throughput
#   optimizer  — the tick()/background decide→apply loop + Autopilot facade
#   drivers    — deterministic workload-drift scenarios (tests/bench/demo)
#   serving    — concurrent frontend: admission, coalescing, tenancy (§11)

from .observer import LogicalClock, Observer
from .cost_model import Calibration, LayoutScore, WhatIfCostModel
from .optimizer import (AppliedDecision, Autopilot, AutopilotConfig,
                        StorageOptimizer, TickReport)
from .drivers import (DriftScenarioReport, aggregate_result,
                      default_drift_config, drift_tables, q_orderkey,
                      q_partkey, run_drift_scenario)
from .serving import (AdmissionError, NamespacedWorkload, ServeTicket,
                      ServingFrontend, Tenant, TenantBudgetError, TENANT_SEP)
