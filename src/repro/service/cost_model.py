"""What-if cost model for candidate layouts (DESIGN §8).

Answers the optimizer's question: *if dataset D were repartitioned into
candidate layout c, how many seconds of shuffle work would the observed
workload mix stop paying, and what does the repartition itself cost?*

Benefit side — for every skeleton group in history whose IR scans D, Alg. 4
(:func:`~repro.core.matching.partitioning_match`) counts the partition
nodes that layout c would elide versus the count the *current* layout
already elides; the delta, times the group's run rate inside the recency
window, times the modeled per-shuffle seconds, is the benefit rate.  Using
the exact matcher means the model never predicts an elision the planner
won't actually compile into the PhysicalPlan (DESIGN §9: the same Alg. 4
check runs statically at plan time).

Cost side — one full repartition of D's bytes.

Both sides are priced from **measured shuffle throughput**, calibrated from
two sources: live timings (the Observer feeds every run's
``shuffle_bytes / shuffle_s``) and committed ``BENCH_*.json`` snapshots
(:meth:`WhatIfCostModel.load_bench_json` parses the repartition rows).
With neither, the paper's 10 Gbps cluster bandwidth is the prior.

Durable stores (DESIGN §10) add an **I/O side**: applying a layout to a
store with ``root=`` also writes the new generation's segments, and a
spilled source must be rehydrated off disk first.  Those bytes are priced
at the measured storage throughput (the Observer feeds every run's
``storage_io_bytes / storage_io_s``; the Autopilot feeds each applied
decision's flush) with an NVMe-class prior before any sample arrives.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core.history import HistoryStore
from ..core.matching import partitioning_match
from ..core.partitioner import PartitionerCandidate

DEFAULT_BANDWIDTH = 1.25e9          # 10 Gbps — the paper's cluster prior
DEFAULT_DISK_BANDWIDTH = 2e9        # NVMe-class prior for the durable tier


@dataclass
class Calibration:
    """Running bytes/seconds totals → measured throughput."""
    bytes_total: float = 0.0
    seconds_total: float = 0.0
    samples: int = 0

    def observe(self, nbytes: float, seconds: float) -> None:
        if nbytes <= 0 or seconds <= 0:
            return
        self.bytes_total += float(nbytes)
        self.seconds_total += float(seconds)
        self.samples += 1

    def throughput(self) -> Optional[float]:
        if self.seconds_total <= 0:
            return None
        return self.bytes_total / self.seconds_total


@dataclass
class LayoutScore:
    """What-if verdict for one (dataset, candidate) pair."""
    dataset: str
    candidate_signature: str
    benefit_s: float          # window'd shuffle seconds saved per window
    repartition_s: float      # modeled one-time cost of applying the layout
    runs_in_window: float     # consumer runs (weight-aware) that scanned D
    shuffles_delta: float     # Σ runs × (elisions_new − elisions_current)
    io_s: float = 0.0         # durable-tier I/O: rehydrate spilled source +
                              # persist the new generation (DESIGN §10)
    padding_benefit_s: float = 0.0   # per-window seconds saved by shrinking
                                     # padded-layout bytes (DESIGN §12)

    @property
    def apply_cost_s(self) -> float:
        """Total one-time cost of applying the layout (shuffle + I/O)."""
        return self.repartition_s + self.io_s

    @property
    def net_s(self) -> float:
        return self.benefit_s + self.padding_benefit_s - self.apply_cost_s

    def explain(self, hysteresis: float, horizon: float = 1.0) -> Dict:
        """The gate math as data — every priced component plus both sides
        of the :meth:`worth_it` inequality, so a why-record can show
        exactly how close a rejected candidate came."""
        amortized = (self.benefit_s + self.padding_benefit_s) * horizon
        gated = hysteresis * self.apply_cost_s
        return {
            "benefit_s": float(self.benefit_s),
            "padding_benefit_s": float(self.padding_benefit_s),
            "repartition_s": float(self.repartition_s),
            "io_s": float(self.io_s),
            "apply_cost_s": float(self.apply_cost_s),
            "net_s": float(self.net_s),
            "runs_in_window": float(self.runs_in_window),
            "shuffles_delta": float(self.shuffles_delta),
            "hysteresis": float(hysteresis),
            "horizon_windows": float(horizon),
            "amortized_benefit_s": float(amortized),
            "gated_cost_s": float(gated),
        }

    def worth_it(self, hysteresis: float, horizon: float = 1.0) -> bool:
        """Modeled benefit must clear the one-time apply cost (repartition
        shuffle + any durable-tier I/O) by the hysteresis factor — the
        flip-flop guard.  ``horizon`` is the number of future recency
        windows the new layout is expected to stay useful: ``benefit_s`` is
        a per-window rate while the apply cost is paid once, so the gate
        amortizes exactly like Eq. 2 trades the producer-side cost against
        future consumer runs."""
        return (self.benefit_s + self.padding_benefit_s) * horizon \
            > hysteresis * self.apply_cost_s


class WhatIfCostModel:
    def __init__(self, default_bandwidth: float = DEFAULT_BANDWIDTH,
                 bench_path: Optional[str] = None,
                 default_disk_bandwidth: float = DEFAULT_DISK_BANDWIDTH):
        self.default_bandwidth = default_bandwidth
        self.default_disk_bandwidth = default_disk_bandwidth
        self.shuffle_cal = Calibration()
        self.repartition_cal = Calibration()
        self.io_cal = Calibration()
        if bench_path:
            self.load_bench_json(bench_path)

    # -- calibration --------------------------------------------------------
    def observe_shuffle(self, nbytes: float, seconds: float) -> None:
        self.shuffle_cal.observe(nbytes, seconds)

    def observe_repartition(self, nbytes: float, seconds: float) -> None:
        self.repartition_cal.observe(nbytes, seconds)

    def observe_io(self, nbytes: float, seconds: float) -> None:
        """Durable-tier sample: segment bytes moved / wall seconds (spill
        flushes, rehydration reads, autoflushed generations)."""
        self.io_cal.observe(nbytes, seconds)

    def load_bench_json(self, path: str) -> int:
        """Best-effort calibration from a committed BENCH_*.json snapshot:
        every ``repartition*`` row whose derived string carries a
        ``bytes=`` figure contributes a throughput sample.  Returns the
        number of samples loaded (0 on parse trouble — never raises)."""
        loaded = 0
        try:
            with open(path) as f:
                rows = json.load(f).get("rows", [])
        except (OSError, ValueError):
            return 0
        for row in rows:
            try:
                if not str(row.get("name", "")).startswith("repartition"):
                    continue
                mb = re.search(r"bytes=(\d+)", str(row.get("derived", "")))
                us = float(row.get("us_per_call", 0.0))
                if mb and us > 0:
                    self.repartition_cal.observe(float(mb.group(1)),
                                                 us * 1e-6)
                    loaded += 1
            except (TypeError, ValueError):
                continue
        return loaded

    # -- modeled times ------------------------------------------------------
    def shuffle_throughput(self) -> float:
        t = self.shuffle_cal.throughput()
        if t is None:
            t = self.repartition_cal.throughput()
        return t if t is not None else self.default_bandwidth

    def repartition_throughput(self) -> float:
        t = self.repartition_cal.throughput()
        if t is None:
            t = self.shuffle_cal.throughput()
        return t if t is not None else self.default_bandwidth

    def shuffle_seconds(self, nbytes: float, num_workers: int) -> float:
        """One consumer-side shuffle of the dataset: (m-1)/m of the bytes
        re-bucket (rows landing on their own worker don't move)."""
        frac = (num_workers - 1) / num_workers if num_workers > 1 else 0.0
        return nbytes * frac / self.shuffle_throughput()

    def repartition_seconds(self, nbytes: float) -> float:
        return nbytes / self.repartition_throughput()

    def io_throughput(self) -> float:
        t = self.io_cal.throughput()
        return t if t is not None else self.default_disk_bandwidth

    def io_seconds(self, nbytes: float) -> float:
        """Durable-tier transfer time for ``nbytes`` of segment data."""
        return nbytes / self.io_throughput()

    def rebalance_seconds(self, moved_bytes: float) -> float:
        """Modeled wall time of an incremental cluster rebalance (DESIGN
        §14): the moved partitions' segment bytes stream node-to-node at
        the calibrated segment-I/O throughput — unchanged parts are
        hard-linked, so only the minimal move set is priced."""
        return self.io_seconds(max(float(moved_bytes), 0.0))

    def padding_overhead_s(self, padded_bytes: float,
                           valid_bytes: float) -> float:
        """Per-run seconds a padded layout wastes moving padding (DESIGN
        §12): the padded-vs-valid byte gap priced at storage throughput —
        padding is paid on every segment write/spill/rehydrate and every
        memmap page-in, which the durable calibration already measures."""
        return max(padded_bytes - valid_bytes, 0.0) / self.io_throughput()

    # -- what-if scoring ----------------------------------------------------
    @staticmethod
    def elisions_per_run(candidate: Optional[PartitionerCandidate],
                         dataset: str, ir) -> int:
        """Partition nodes of one consumer IR that layout `candidate` lets
        the planner elide — the exact Alg. 4 check the planner compiles
        into the PhysicalPlan at plan time."""
        if candidate is None or not candidate.is_keyed:
            return 0
        return len(partitioning_match(candidate, dataset, ir).partition_nodes)

    def score(self, dataset: str, ds_bytes: float, num_workers: int,
              candidate: PartitionerCandidate,
              current: Optional[PartitionerCandidate],
              history: HistoryStore, *, now: float,
              window_s: float = float("inf"),
              groups: Optional[Dict] = None,
              durable: bool = False,
              source_spilled: bool = False,
              current_padded_bytes: float = 0.0,
              current_valid_bytes: float = 0.0,
              candidate_padded_bytes: Optional[float] = None,
              local: bool = False) -> LayoutScore:
        """What-if score of moving ``dataset`` from layout ``current`` to
        ``candidate``, against the run mix observed inside the recency
        window ``[now - window_s, now]`` (drifted-away workloads age out).
        Pass a prebuilt skeleton ``groups`` dict to amortize the graph
        build across many scores of one history snapshot.

        ``durable`` charges persisting the repartitioned generation's
        segments; ``source_spilled`` additionally charges rehydrating the
        evicted source off disk before it can be shuffled (DESIGN §10).

        Padding term (DESIGN §12): pass the current layout's
        padded/valid bytes plus the candidate layout's estimated padded
        bytes and the per-run padding-overhead delta is added to the
        benefit rate — how split/merge decisions pay for themselves even
        when they change no elision.  ``local=True`` prices the apply as a
        node-local rewrite (rebucket: same partitioner, no rows cross the
        network) at I/O throughput instead of a full shuffle."""
        per_shuffle_s = self.shuffle_seconds(ds_bytes, num_workers)
        io_s = 0.0
        if durable:
            io_s += self.io_seconds(ds_bytes)
        if source_spilled:
            io_s += self.io_seconds(ds_bytes)
        if groups is None:
            groups, _ = history.skeleton_graph()
        benefit = 0.0
        runs_in_window = 0.0
        shuffles_delta = 0.0
        for sig, group in groups.items():
            ir = history.ir_of(sig)
            if ir is None or ir.find_scanner(dataset) is None:
                continue
            rate = sum(r.weight for r in group.runs
                       if r.timestamp >= now - window_s)
            if rate <= 0:
                continue
            runs_in_window += rate
            delta = (self.elisions_per_run(candidate, dataset, ir)
                     - self.elisions_per_run(current, dataset, ir))
            shuffles_delta += rate * delta
            benefit += rate * delta * per_shuffle_s
        padding_benefit = 0.0
        if candidate_padded_bytes is not None and runs_in_window > 0:
            padding_benefit = runs_in_window * (
                self.padding_overhead_s(current_padded_bytes,
                                        current_valid_bytes)
                - self.padding_overhead_s(candidate_padded_bytes,
                                          current_valid_bytes))
        return LayoutScore(
            dataset=dataset, candidate_signature=candidate.signature(),
            benefit_s=benefit,
            repartition_s=(self.io_seconds(ds_bytes) if local
                           else self.repartition_seconds(ds_bytes)),
            runs_in_window=runs_in_window, shuffles_delta=shuffles_delta,
            io_s=io_s, padding_benefit_s=padding_benefit)
