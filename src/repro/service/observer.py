"""Observer — the "observe" third of the Autopilot loop (DESIGN §8).

Attaches to run hooks — of a :class:`~repro.api.Session` or the legacy
:class:`~repro.core.engine.Engine` shim — and turns every execution into
durable signal: an :class:`~repro.core.history.
ExecutionRecord` appended to the :class:`~repro.core.history.HistoryStore`
(latency, input/output bytes, per-candidate selectivity/distinct-key stats
measured at each partition node), plus live shuffle-throughput samples fed
to the :class:`~repro.service.cost_model.WhatIfCostModel` calibration.
The measurement pass at partition nodes only runs while an observer (or
any other hook/history) is attached; unobserved runs skip it.

Timestamps come from a pluggable clock.  Production uses ``time.time``;
tests and the drift scenarios use :class:`LogicalClock` so the recency
window of the cost model is deterministic under ``tick()``.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

from ..core.executor import EngineStats
from ..core.history import ExecutionRecord, HistoryStore


class LogicalClock:
    """Deterministic clock: each ``()`` call returns the next tick.

    ``peek()`` reads without advancing (the optimizer uses it so scoring a
    tick does not age the history it scores)."""

    def __init__(self, start: float = 0.0, step: float = 1.0):
        self._now = float(start)
        self.step = float(step)

    def __call__(self) -> float:
        self._now += self.step
        return self._now

    def peek(self) -> float:
        return self._now


class Observer:
    """Auto-appends an ExecutionRecord per observed Session/Engine run.

    ``attach(engine)`` registers a run hook; from then on every run of that
    engine is recorded with this observer's clock — no hand-built records.
    ``max_records`` (optional) auto-compacts the history so the log stays
    bounded under continuous service writes; compaction (a full-log merge
    + JSONL rewrite) only triggers once the log exceeds ``max_records``
    by ``compact_slack`` records, so steady state amortizes the rewrite
    over ~slack appends instead of paying it on every run.
    """

    def __init__(self, history: Optional[HistoryStore] = None, *,
                 clock: Callable[[], float] = time.time,
                 cost_model=None,
                 max_records: Optional[int] = None,
                 compact_slack: Optional[int] = None):
        self.history = history if history is not None else HistoryStore()
        self.clock = clock
        self.cost_model = cost_model
        self.max_records = max_records
        if compact_slack is None and max_records is not None:
            compact_slack = max(8, max_records // 2)
        self.compact_slack = compact_slack
        self.records_seen = 0
        self.compacted_total = 0

    def attach(self, session) -> "Observer":
        """Register on anything with ``add_run_hook`` (Session or the
        legacy Engine shim)."""
        session.add_run_hook(self.on_run)
        return self

    # -- the hook -----------------------------------------------------------
    def on_run(self, workload, stats: EngineStats) -> ExecutionRecord:
        # per-run dedupe: when THIS run's executor already appended its
        # record to this exact HistoryStore (session/engine constructed
        # with history=..., or run(history=...) passed explicitly), adopt
        # that record instead of logging a duplicate — double records
        # would double the run rates the cost model prices from
        if stats.history_logged is self.history and self.history.records:
            rec = self.history.records[-1]      # the executor's append
        else:
            rec = self.history.log_workload(
                workload, timestamp=self.clock(), latency=stats.wall_s,
                input_bytes=float(stats.input_bytes),
                output_bytes=float(stats.output_bytes),
                padded_bytes=float(stats.padded_bytes),
                valid_bytes=float(stats.valid_bytes),
                candidate_stats=dict(stats.candidate_stats or {}))
        self.records_seen += 1
        if self.cost_model is not None and stats.shuffle_bytes \
                and stats.shuffle_s > 0:
            self.cost_model.observe_shuffle(stats.shuffle_bytes,
                                            stats.shuffle_s)
        # durable-tier calibration (DESIGN §10): live segment I/O this run
        # caused (autoflushed writes, spill rehydration) prices the cost
        # model's spill/load charges
        if self.cost_model is not None and stats.storage_io_bytes \
                and stats.storage_io_s > 0:
            self.cost_model.observe_io(stats.storage_io_bytes,
                                       stats.storage_io_s)
        if self.max_records is not None and len(self.history.records) \
                >= self.max_records + self.compact_slack:
            self.compacted_total += self.history.compact(self.max_records)
        return rec
