"""Drift scenarios for the Autopilot service (DESIGN §8).

A deterministic end-to-end exercise of observe → decide → repartition:
TPC-H-like tables start round-robin; an orderkey-join mix (Q04 family)
runs until the optimizer autonomously partitions lineitem/orders by
orderkey and the joins stop shuffling; then the mix *drifts* to a
partkey-join (Q17 family) and the service re-partitions lineitem again —
away from the now-stale orderkey layout — all through ``tick()`` with a
:class:`~repro.service.observer.LogicalClock`, so tests, the example and
the benchmark replay the exact same sequence.

Payload columns are integer-valued floats: keyed sums of exactly
representable integers are order-independent, so query results across
layout generations compare **bit-for-bit** even though row order inside
worker segments changes with the layout.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..api import Session
from ..core.dsl import Workload
from ..core.executor import EngineStats
from ..data.partition_store import PartitionStore
from ..data.skew import zipf_keys
from .observer import LogicalClock
from .optimizer import Autopilot, AutopilotConfig, TickReport


# -- workload mix ------------------------------------------------------------

def q_orderkey() -> Workload:
    """Q04-family: join lineitem with orders on orderkey, aggregate."""
    wl = Workload("q-orderkey")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    agg = wl.aggregate(j, key=j["odate"], reducer="sum")
    wl.write(agg, "q_orderkey_out")
    return wl


def q_partkey() -> Workload:
    """Q17-family: join lineitem with part on partkey, aggregate."""
    wl = Workload("q-partkey")
    li = wl.scan("lineitem")
    pt = wl.scan("part")
    j = wl.join(li, pt, left_key=li["partkey"], right_key=pt["partkey"],
                tag="li_part")
    agg = wl.aggregate(j, key=j["size"], reducer="sum")
    wl.write(agg, "q_partkey_out")
    return wl


def drift_tables(n_lineitem: int = 6000, n_orders: int = 1500,
                 n_parts: int = 300, seed: int = 0,
                 skew: float = 0.0) -> Dict[str, Dict[str, np.ndarray]]:
    """Synthetic TPC-H-ish tables.  All payloads are integer-valued so
    keyed float sums are exact (bit-identical across layouts).  ``skew>0``
    draws lineitem orderkeys from a Zipf-like tail — the skewed-keys
    scenario (padding waste shows up in ``StoredDataset.skew()``)."""
    rng = np.random.default_rng(seed)
    if skew > 0:
        li_orderkey = zipf_keys(n_lineitem, n_orders, 1.0 + skew, rng=rng)
    else:
        li_orderkey = rng.integers(0, n_orders, n_lineitem)
    lineitem = {"orderkey": li_orderkey,
                "partkey": rng.integers(0, n_parts, n_lineitem),
                "qty": rng.integers(1, 50, n_lineitem).astype(np.float32),
                "price": rng.integers(50, 150,
                                      n_lineitem).astype(np.float32)}
    orders = {"orderkey": np.arange(n_orders, dtype=np.int64),
              "odate": rng.integers(0, 90, n_orders).astype(np.int32)}
    part = {"partkey": np.arange(n_parts, dtype=np.int64),
            "size": rng.integers(1, 50, n_parts).astype(np.int32)}
    return {"lineitem": lineitem, "orders": orders, "part": part}


def aggregate_result(vals, workload) -> Dict[str, np.ndarray]:
    """Canonical (key-sorted) columns of the workload's final aggregate —
    hash layouts give every key exactly one output row, so sorting by key
    makes results comparable bit-for-bit across layout generations."""
    node = max(n for n, nd in workload.graph.nodes.items()
               if nd.kind == "aggregate")
    tv = vals[node]
    order = np.argsort(tv.columns["key"], kind="stable")
    return {k: np.ascontiguousarray(np.asarray(v)[order])
            for k, v in tv.columns.items()}


# -- the scenario ------------------------------------------------------------

@dataclass
class RunSummary:
    wall_s: float
    shuffles: int
    elided: int
    shuffle_bytes: int
    device_repartitions: int

    @classmethod
    def of(cls, stats: EngineStats) -> "RunSummary":
        return cls(wall_s=stats.wall_s, shuffles=stats.shuffles_performed,
                   elided=stats.shuffles_elided,
                   shuffle_bytes=stats.shuffle_bytes,
                   device_repartitions=stats.device_repartitions)


@dataclass
class DriftScenarioReport:
    store: PartitionStore
    session: Session
    autopilot: Autopilot
    phase_a: List[RunSummary] = field(default_factory=list)
    tick_a: Optional[TickReport] = None
    post_a: Optional[RunSummary] = None
    result_pre_a: Optional[Dict[str, np.ndarray]] = None
    result_post_a: Optional[Dict[str, np.ndarray]] = None
    phase_b: List[RunSummary] = field(default_factory=list)
    tick_b_mid: Optional[TickReport] = None   # early tick: lineitem/orders
    tick_b: Optional[TickReport] = None       # still cooling down
    post_b: Optional[RunSummary] = None
    result_pre_b: Optional[Dict[str, np.ndarray]] = None
    result_post_b: Optional[Dict[str, np.ndarray]] = None
    lineitem_generations: List[int] = field(default_factory=list)
    lineitem_partitioners: List[str] = field(default_factory=list)


def default_drift_config() -> AutopilotConfig:
    """Recency window short enough that phase-A workloads age out during
    phase B — the knob that makes the service *follow* the drift.

    Hysteresis sits at 1.0 (not the service default 1.5): the first
    repartition's measured wall includes the candidate key-projection's
    one-time jit compile, which understates repartition throughput on a
    cold process; the cooldown and same-signature checks remain the
    flip-flop guards, and the scenario stays deterministic with a wide
    gate margin instead of a knife-edge one."""
    return AutopilotConfig(window_s=6.0, hysteresis=1.0, min_runs=2.0,
                           cooldown_ticks=1)


def run_drift_scenario(*, backend: str = "host", num_workers: int = 8,
                       n_lineitem: int = 12000, n_orders: int = 1500,
                       n_parts: int = 300, seed: int = 0, skew: float = 0.0,
                       phase_a_runs: int = 3, phase_b_runs: int = 6,
                       config: Optional[AutopilotConfig] = None,
                       selector=None) -> DriftScenarioReport:
    """Run the full drift scenario deterministically via ``tick()``."""
    tables = drift_tables(n_lineitem, n_orders, n_parts, seed, skew)
    store = PartitionStore(num_workers=num_workers, backend=backend)
    for name, data in tables.items():
        store.write(name, data)                       # round-robin seed
    session = Session(store, backend=backend)
    ap = session.autopilot(clock=LogicalClock(),
                           config=config or default_drift_config(),
                           selector=selector)
    rep = DriftScenarioReport(store=store, session=session, autopilot=ap)

    def snap_lineitem():
        ds = store.read("lineitem")
        rep.lineitem_generations.append(ds.generation)
        rep.lineitem_partitioners.append(
            ds.partitioner.signature() if ds.partitioner else "none")

    wl_a, wl_b = q_orderkey(), q_partkey()
    snap_lineitem()

    # phase A: orderkey mix — every run observed, shuffles paid
    for i in range(phase_a_runs):
        vals, stats = session.run(wl_a)
        rep.phase_a.append(RunSummary.of(stats))
        if i == 0:
            rep.result_pre_a = aggregate_result(vals, wl_a)
    rep.tick_a = ap.tick()                            # decide + apply + swap
    snap_lineitem()
    vals, stats = session.run(wl_a)                    # post-decision run
    rep.post_a = RunSummary.of(stats)
    rep.result_post_a = aggregate_result(vals, wl_a)

    # phase B: the mix drifts to partkey joins.  An early tick lands inside
    # lineitem/orders' post-swap cooldown, so it cannot flip them yet (the
    # flip-flop guard); `part` — new traffic, no cooldown — may be acted on.
    for i in range(phase_b_runs):
        vals, stats = session.run(wl_b)
        rep.phase_b.append(RunSummary.of(stats))
        if i == 0:
            rep.result_pre_b = aggregate_result(vals, wl_b)
        if i == 1:
            rep.tick_b_mid = ap.tick()
    rep.tick_b = ap.tick()                            # re-partition on drift
    snap_lineitem()
    vals, stats = session.run(wl_b)
    rep.post_b = RunSummary.of(stats)
    rep.result_post_b = aggregate_result(vals, wl_b)
    return rep
