"""Serving tier — one shared store, many live sessions (DESIGN §11).

The paper's claim is that Lachesis optimizes storage *across
applications*; this module is the execution surface where many
applications actually coexist.  A :class:`ServingFrontend` admits
concurrent workloads against one shared
:class:`~repro.data.partition_store.PartitionStore` through the same
Planner/Executor stack a single :class:`~repro.api.Session` uses — the
whole point of the thread-safety work in the store (lock-free
generation-pointer reads), the planner (locked PhysicalPlan cache) and
the executor (one up-front scan snapshot per run):

* **Admission + backpressure** — a bounded thread pool with a bounded
  wait queue.  A full queue rejects (:class:`AdmissionError`) or blocks,
  caller's choice, so overload degrades service latency instead of
  memory.
* **Request coalescing** — identical *read-only* requests (same plan-
  cache key, i.e. same IR × params × backend × layout generations) share
  one execution: a plan-cache hit already costs ~12–30 µs, so the only
  thing worth deduplicating is the execution itself.  A generation flip
  changes the key, so coalescing never crosses layouts.
* **Tenancy** — tenants own disjoint dataset-name prefixes inside the
  shared store, each with an optional byte budget
  (:class:`TenantBudgetError` on the offender only) and fault isolation:
  one tenant's failing UDF fails that tenant's ticket, nothing else.
* **MVCC under the Autopilot** — a background repartition publishes a new
  generation with one atomic pointer flip; in-flight runs hold the
  StoredDataset objects of the generation they resolved, and queued runs
  transparently re-plan on ``StalePlanError``/``RetiredGenerationError``.
  Live readers never stall and never observe a half-shuffled table.

Usage::

    sess = lachesis.Session(num_workers=8)
    front = sess.serve(max_workers=8, max_queue=64)
    alice = front.tenant("alice", memory_budget_bytes=1 << 30)
    alice.write("events", events_cols, cand)
    wl = alice.workload(); wl.write(wl.aggregate(...), "daily")
    ticket = front.submit(wl)           # -> ServeTicket (a future)
    result = ticket.result(timeout=30)  # RunResult, same as Session.run
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.dsl import SetHandle, Workload
from ..obs.tracer import TRACER as _TRACER, span as _span

__all__ = ["ServingFrontend", "ServeTicket", "Tenant", "NamespacedWorkload",
           "AdmissionError", "TenantBudgetError", "TENANT_SEP"]

#: separates the tenant namespace from the dataset name inside the store
TENANT_SEP = "::"


class AdmissionError(RuntimeError):
    """The frontend's bounded queue is full — backpressure.  Retry later,
    or submit with ``block=True`` to wait for a slot."""


class TenantBudgetError(RuntimeError):
    """A tenant write would exceed that tenant's byte budget.  Only the
    offending tenant sees this; other tenants' traffic is unaffected."""


class NamespacedWorkload(Workload):
    """A Workload whose ``scan``/``write`` dataset names are transparently
    qualified with a tenant prefix — tenant code reads and writes short
    names while the shared store keys everything by namespace."""

    def __init__(self, app_id: str, prefix: str):
        super().__init__(app_id)
        self.prefix = prefix

    def _qualify(self, dataset: str) -> str:
        if dataset.startswith(self.prefix):
            return dataset
        return self.prefix + dataset

    def scan(self, dataset: str) -> SetHandle:
        return super().scan(self._qualify(dataset))

    def write(self, x: SetHandle, dataset: str) -> SetHandle:
        return super().write(x, self._qualify(dataset))


class ServeTicket:
    """Admission receipt for one submitted workload — a future.

    ``result()`` blocks until the run completes and returns the same
    :class:`~repro.api.RunResult` a synchronous ``Session.run`` would
    have; a failed run re-raises the worker's exception here (and only
    here — failures are per-ticket).  Coalesced submissions share one
    ticket: every caller of ``result()`` sees the single execution."""

    def __init__(self, key: Optional[Tuple] = None):
        self.key = key
        self.submitted_at = time.perf_counter()
        self.finished_at: Optional[float] = None
        self.coalesced_with = 0          # followers sharing this execution
        self._done = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        # tracing link back to the submitting thread's span (None when
        # tracing is off): the worker attaches it so the ticket's spans
        # parent across the pool handoff
        self._trace_ctx = _TRACER.context()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("serving ticket not finished "
                               f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finished_at is None \
            else self.finished_at - self.submitted_at

    # -- frontend internals --------------------------------------------------
    def _finish(self, result=None, error: Optional[BaseException] = None):
        self._result = result
        self._error = error
        self.finished_at = time.perf_counter()
        self._done.set()


class Tenant:
    """One tenant's view of the shared store: a dataset-name namespace, an
    optional byte budget, and submission sugar.  Obtained via
    :meth:`ServingFrontend.tenant`."""

    def __init__(self, frontend: "ServingFrontend", name: str,
                 memory_budget_bytes: Optional[int] = None):
        if TENANT_SEP in name:
            raise ValueError(f"tenant name may not contain {TENANT_SEP!r}")
        self.frontend = frontend
        self.name = name
        self.memory_budget_bytes = memory_budget_bytes
        self._wl_counter = 0

    @property
    def prefix(self) -> str:
        return self.name + TENANT_SEP

    def qualify(self, dataset: str) -> str:
        return dataset if dataset.startswith(self.prefix) \
            else self.prefix + dataset

    def used_bytes(self) -> int:
        """Logical bytes of this tenant's current-generation datasets."""
        return self.frontend.store.namespace_bytes(self.prefix)

    def workload(self, app_id: Optional[str] = None) -> NamespacedWorkload:
        if app_id is None:
            self._wl_counter += 1
            app_id = f"{self.name}-wl-{self._wl_counter}"
        return NamespacedWorkload(app_id, self.prefix)

    def write(self, name: str, data: Dict[str, Any], partitioner=None,
              seed: int = 0):
        """Store host columns under this tenant's namespace, enforcing the
        tenant budget BEFORE any bytes land — an over-budget write raises
        :class:`TenantBudgetError` and changes nothing."""
        incoming = int(sum(np.asarray(v).nbytes for v in data.values()))
        if self.memory_budget_bytes is not None:
            used = self.used_bytes()
            if used + incoming > self.memory_budget_bytes:
                raise TenantBudgetError(
                    f"tenant {self.name!r}: write of {incoming} B would "
                    f"exceed budget ({used} used of "
                    f"{self.memory_budget_bytes} B)")
        return self.frontend.store.write(self.qualify(name), data,
                                         partitioner, seed=seed)

    def read(self, name: str, generation: Optional[int] = None):
        return self.frontend.store.read(self.qualify(name),
                                        generation=generation)

    def submit(self, workload: Workload, **kw) -> ServeTicket:
        return self.frontend.submit(workload, tenant=self.name, **kw)

    def run(self, workload: Workload, *, timeout: Optional[float] = None,
            **kw):
        return self.submit(workload, **kw).result(timeout)


@dataclass
class _Counters:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    coalesced: int = 0
    completed: int = 0
    failed: int = 0
    latencies_s: List[float] = field(default_factory=list)


class ServingFrontend:
    """Admits many concurrent workloads against one shared store.

    Wraps an existing :class:`~repro.api.Session` (idiomatically via
    ``session.serve()``) and shares its Planner — so the PhysicalPlan
    cache, and therefore the coalescing identity, is the same one the
    session uses — and its Executor, which is reentrant: all run state
    lives in the plan and the per-run value table.

    ``max_workers`` bounds concurrent executions; ``max_queue`` bounds
    *waiting* admissions beyond that — the backpressure surface.
    ``observe=True`` routes every serve through the session's run hooks
    and history, feeding an attached Autopilot exactly as synchronous
    runs do."""

    def __init__(self, session, *, max_workers: int = 8,
                 max_queue: int = 64, coalesce: bool = True,
                 observe: bool = True):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        if max_queue < 0:
            raise ValueError("max_queue must be >= 0")
        self.session = session
        self.planner = session.planner
        self.executor = session.executor
        self.max_workers = max_workers
        self.max_queue = max_queue
        self.coalesce_default = coalesce
        self.observe = observe
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="lachesis-serve")
        self._slots = threading.BoundedSemaphore(max_workers + max_queue)
        self._inflight: Dict[Tuple, ServeTicket] = {}
        self._inflight_lock = threading.Lock()
        self._counters = _Counters()
        self._counters_lock = threading.Lock()
        self._tenants: Dict[str, Tenant] = {}
        self._closed = False
        # metrics (DESIGN §13): counters stay in _Counters (stats() is
        # the authoritative view); the registry gets them via a snapshot
        # callback plus a real latency histogram, labeled per frontend
        self._metric_labels = {"frontend":
                               f"f{next(ServingFrontend._ids)}"}
        reg = getattr(session, "metrics_registry", None)
        self._latency_hist = None
        if reg is not None:
            self._latency_hist = reg.histogram(
                "serving_latency_seconds", "serve ticket latency",
                self._metric_labels)
            reg.register_callback(self, ServingFrontend._metric_samples)

    _ids = itertools.count(1)

    def _metric_samples(self):
        for k, v in self.stats().items():
            yield f"serving_{k}", self._metric_labels, float(v)

    def metrics(self) -> Dict[str, Any]:
        """Versioned JSON snapshot of the session registry this frontend
        reports into (serving counters + latency histogram included)."""
        return self.session.metrics_registry.snapshot()

    def metrics_text(self) -> str:
        return self.session.metrics_registry.prometheus_text()

    @property
    def store(self):
        return self.session.store

    # -- tenancy -------------------------------------------------------------
    def tenant(self, name: str,
               memory_budget_bytes: Optional[int] = None) -> Tenant:
        """The named tenant's view (created on first use; a later call may
        tighten or lift its budget by passing ``memory_budget_bytes``)."""
        t = self._tenants.get(name)
        if t is None:
            t = self._tenants.setdefault(
                name, Tenant(self, name, memory_budget_bytes))
        if memory_budget_bytes is not None:
            t.memory_budget_bytes = memory_budget_bytes
        return t

    # -- submission ----------------------------------------------------------
    def submit(self, workload: Workload, *, backend: Optional[str] = None,
               tenant: Optional[str] = None, coalesce: Optional[bool] = None,
               block: bool = False,
               timeout: Optional[float] = None) -> ServeTicket:
        """Admit ``workload``; returns a :class:`ServeTicket` immediately.

        Admission order: (1) an identical in-flight read-only request
        coalesces for free — no queue slot consumed; (2) otherwise a
        queue slot is acquired (``block=False`` raises
        :class:`AdmissionError` when the queue is full; ``block=True``
        waits up to ``timeout``) and the run is dispatched to the pool."""
        if self._closed:
            raise RuntimeError("frontend is closed")
        with self._counters_lock:
            self._counters.submitted += 1
        backend_name = (self.session.backend if backend is None else backend)

        with _span("serve.submit", "serve", tenant=tenant or "",
                   workload=getattr(workload, "app_id", "?")) as sub_sp:
            key: Optional[Tuple] = None
            if (self.coalesce_default if coalesce is None else coalesce) \
                    and self._read_only(workload):
                # the PhysicalPlan cache key IS the coalescing identity:
                # IR × params × backend × workers × layout generations.
                # Identical queued requests resolve the same key; a
                # concurrent generation flip changes it, so no
                # cross-layout sharing.
                key = (tenant, self.planner.plan_key(workload, backend_name))
                with self._inflight_lock:
                    leader = self._inflight.get(key)
                    if leader is not None and not leader.done():
                        leader.coalesced_with += 1
                        with self._counters_lock:
                            self._counters.coalesced += 1
                        sub_sp.set(outcome="coalesced")
                        return leader

            admitted = self._slots.acquire(timeout=timeout) if block \
                else self._slots.acquire(blocking=False)
            if not admitted:
                with self._counters_lock:
                    self._counters.rejected += 1
                sub_sp.set(outcome="rejected")
                raise AdmissionError(
                    f"serving queue full ({self.max_workers} workers + "
                    f"{self.max_queue} waiting); retry or submit(block=True)")
            ticket = ServeTicket(key=key)
            if key is not None:
                with self._inflight_lock:
                    self._inflight[key] = ticket
            with self._counters_lock:
                self._counters.admitted += 1
            sub_sp.set(outcome="admitted")
            self._pool.submit(self._run_ticket, ticket, workload,
                              backend_name)
            return ticket

    def run(self, workload: Workload, *, timeout: Optional[float] = None,
            **kw):
        """Synchronous convenience: ``submit(...).result(timeout)``."""
        return self.submit(workload, **kw).result(timeout)

    @staticmethod
    def _read_only(workload: Workload) -> bool:
        g = workload.graph
        return not any(n.kind == "write" for n in g.nodes.values())

    # -- the worker ----------------------------------------------------------
    def _run_ticket(self, ticket: ServeTicket, workload: Workload,
                    backend: str) -> None:
        from ..api import RunResult
        from ..core.executor import plan_and_execute
        try:
            # adopt the submitting thread's span as parent (cross-pool
            # link; no-op when tracing is off or was off at submit time)
            with _TRACER.attach(ticket._trace_ctx), \
                    _span("serve.ticket", "serve",
                          workload=getattr(workload, "app_id", "?")) as tsp:
                hooks = tuple(self.session.run_hooks) if self.observe else ()
                history = self.session.history if self.observe else None
                vals, stats, plan = plan_and_execute(
                    self.planner, self.executor, workload, backend,
                    history=history, hooks=hooks)
                tsp.set(cache_hit=stats.plan_cache_hit,
                        coalesced_with=ticket.coalesced_with)
            ticket._finish(result=RunResult(values=vals, stats=stats,
                                            plan=plan, workload=workload))
            with self._counters_lock:
                self._counters.completed += 1
                self._counters.latencies_s.append(ticket.latency_s)
            if self._latency_hist is not None:
                self._latency_hist.observe(ticket.latency_s)
        except BaseException as e:       # noqa: BLE001 — per-ticket isolation
            ticket._finish(error=e)
            with self._counters_lock:
                self._counters.failed += 1
        finally:
            if ticket.key is not None:
                with self._inflight_lock:
                    if self._inflight.get(ticket.key) is ticket:
                        del self._inflight[ticket.key]
            self._slots.release()

    # -- introspection -------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Counters + latency percentiles over completed serves."""
        with self._counters_lock:
            c = self._counters
            lat = np.asarray(c.latencies_s, np.float64)
            out: Dict[str, float] = {
                "submitted": c.submitted, "admitted": c.admitted,
                "rejected": c.rejected, "coalesced": c.coalesced,
                "completed": c.completed, "failed": c.failed,
                "inflight": len(self._inflight),
            }
        if lat.size:
            out["p50_ms"] = float(np.percentile(lat, 50) * 1e3)
            out["p99_ms"] = float(np.percentile(lat, 99) * 1e3)
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        self._closed = True
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
