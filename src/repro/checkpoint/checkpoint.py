"""Fault-tolerant sharded checkpointing.

Layout: ``<dir>/step_<N>/{manifest.json, arr_<i>.npy...}`` written via a
temp directory + atomic rename, so a crash mid-write never corrupts the
latest valid checkpoint.  Restore reads the manifest, loads each leaf, and
re-applies the recorded shardings on the *current* mesh — which may differ
from the mesh at save time (elastic restart), in which case arrays are
resharded on load.  The manifest also records the data-pipeline cursor and
RNG key so training resumes exactly-once.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        paths, leaves, _ = _flatten_with_paths(state)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({"path": p, "file": fname,
                                       "dtype": str(arr.dtype),
                                       "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc_old(directory, keep=3)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, state_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``state_like``; optionally re-apply
    ``shardings`` (same pytree structure or a single sharding) on load."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(state_like)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else None)
    for i, (p, like) in enumerate(zip(paths, leaves)):
        rec = by_path.get(p)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = np.load(os.path.join(d, rec["file"]))
        if shard_leaves is not None:
            sh = shard_leaves[i if len(shard_leaves) > 1 else 0]
            arr = jax.device_put(arr, sh)
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves), step, manifest.get("extra", {})


def _gc_old(directory: str, keep: int) -> None:
    steps = sorted([d for d in os.listdir(directory) if d.startswith("step_")])
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
