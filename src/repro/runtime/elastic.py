"""Elastic scaling: recompute the mesh after node loss/gain.

Policy: keep the model axis intact (TP sharding is layout-critical), shrink
the data axis to the largest size the surviving chip count supports, and
emit a deterministic resharding plan (which checkpoint shards each new
device loads).  Growing back follows the same path in reverse.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def replan_mesh(current: MeshPlan, surviving_devices: int) -> MeshPlan:
    """Largest mesh ≤ surviving devices preserving the model axis.

    data axis shrinks to the largest power-of-two fit (keeps per-device
    batch integral when the global batch is a power-of-two multiple)."""
    axes = current.axes
    model = current.shape[-1]
    if surviving_devices < model:
        raise ValueError("fewer surviving devices than the model axis — "
                         "cannot preserve TP layout; full restart required")
    budget = surviving_devices // model
    data = 1
    while data * 2 <= budget:
        data *= 2
    if "pod" in axes:
        # collapse pod into data when a pod is degraded
        return MeshPlan((1, data, model), axes)
    return MeshPlan((data, model), axes)


def resharding_plan(old: MeshPlan, new: MeshPlan,
                    batch_dim: int) -> Dict[str, object]:
    """Deterministic plan for moving from ``old`` to ``new``:
    which old data-shard ranges each new data shard reads."""
    old_data = old.shape[-2] * (old.shape[0] if len(old.shape) == 3 else 1)
    new_data = new.shape[-2] * (new.shape[0] if len(new.shape) == 3 else 1)
    per_old = batch_dim // old_data
    per_new = batch_dim // new_data
    assignments: List[Dict] = []
    for d in range(new_data):
        lo, hi = d * per_new, (d + 1) * per_new
        src = sorted({lo // per_old, (hi - 1) // per_old})
        assignments.append({"new_shard": d, "rows": (lo, hi),
                            "reads_old_shards": src})
    return {"old": old, "new": new, "per_device_batch": per_new,
            "assignments": assignments}
