"""Straggler mitigation for the data path.

Synchronous SPMD can't drop a slow *device*, but the host-side data pipeline
can and must tolerate slow shards: the dominant production straggler mode is
a host whose input shard is late.  We reissue late shards to backup hosts
(speculative execution, MapReduce-style) and take whichever copy lands
first; the deterministic TokenSource makes duplicates byte-identical so the
race is benign.

Detection: a shard is a straggler once its latency exceeds
``factor ×`` the running p50 over a sliding window.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class StragglerConfig:
    factor: float = 2.0          # straggler if latency > factor * p50
    window: int = 64             # sliding window of completed shard times
    min_samples: int = 8


class StragglerMitigator:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.samples: Deque[float] = deque(maxlen=cfg.window)
        self.reissues = 0
        self.detections: List[Tuple[int, int, float]] = []   # (step, host, lat)

    def threshold(self) -> Optional[float]:
        if len(self.samples) < self.cfg.min_samples:
            return None
        return float(np.percentile(self.samples, 50)) * self.cfg.factor

    def record(self, latency: float) -> None:
        self.samples.append(latency)

    def fetch_shard(self, fetch: Callable[[int, int], dict], step: int,
                    host: int, backup_host: int,
                    simulated_latency: Optional[float] = None) -> dict:
        """Fetch one host's shard; reissue to a backup if it straggles.

        ``simulated_latency`` lets tests inject slowness without sleeping."""
        t0 = time.perf_counter()
        shard = fetch(step, host)
        lat = (simulated_latency if simulated_latency is not None
               else time.perf_counter() - t0)
        thr = self.threshold()
        if thr is not None and lat > thr:
            self.detections.append((step, host, lat))
            self.reissues += 1
            # backup host recomputes the SAME (step, host) shard; determinism
            # of TokenSource makes the duplicate byte-identical
            shard = fetch(step, host)
        self.record(lat)
        return shard
