"""Fault tolerance: heartbeats, failure detection, restart policy.

At 1000+ nodes, node loss is routine.  The control plane here is
deliberately simple and deterministic so it can be tested on one host:

* every worker posts a heartbeat each step; the coordinator marks a worker
  failed after ``miss_threshold`` missed beats;
* on failure, the run transitions to RECOVERING: the coordinator picks the
  restart step (latest complete checkpoint), computes the surviving-node
  mesh via :mod:`repro.runtime.elastic`, and replays the data stream from
  the checkpoint cursor (exactly-once — see data/pipeline.TokenSource);
* repeated failures back off exponentially to avoid restart storms.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional


class RunState(Enum):
    RUNNING = "running"
    RECOVERING = "recovering"
    FAILED = "failed"


@dataclass
class WorkerHealth:
    last_beat_step: int = 0
    missed: int = 0
    alive: bool = True


@dataclass
class FailureEvent:
    step: int
    worker: int
    restart_step: int


class Coordinator:
    def __init__(self, num_workers: int, miss_threshold: int = 3,
                 max_restarts: int = 10):
        self.workers: Dict[int, WorkerHealth] = {
            w: WorkerHealth() for w in range(num_workers)}
        self.miss_threshold = miss_threshold
        self.max_restarts = max_restarts
        self.state = RunState.RUNNING
        self.events: List[FailureEvent] = []
        self.restarts = 0

    def heartbeat(self, worker: int, step: int) -> None:
        h = self.workers[worker]
        h.last_beat_step = step
        h.missed = 0

    def tick(self, step: int, checkpoint_step: int) -> Optional[FailureEvent]:
        """Advance failure detection one step; returns an event on failure."""
        for w, h in self.workers.items():
            if not h.alive:
                continue
            if h.last_beat_step < step:
                h.missed += 1
            if h.missed >= self.miss_threshold:
                h.alive = False
                self.restarts += 1
                ev = FailureEvent(step=step, worker=w,
                                  restart_step=checkpoint_step)
                self.events.append(ev)
                self.state = (RunState.FAILED
                              if self.restarts > self.max_restarts
                              else RunState.RECOVERING)
                return ev
        return None

    def alive_workers(self) -> List[int]:
        return [w for w, h in self.workers.items() if h.alive]

    def backoff_s(self) -> float:
        return min(60.0, 0.1 * (2 ** max(0, self.restarts - 1)))

    def recover(self) -> None:
        if self.state == RunState.RECOVERING:
            self.state = RunState.RUNNING


def run_with_restarts(train_fn: Callable[[int], int], *, total_steps: int,
                      coordinator: Coordinator,
                      restore_fn: Callable[[], int],
                      max_attempts: int = 12) -> int:
    """Drive ``train_fn(start_step) -> reached_step`` to completion across
    simulated failures; ``restore_fn`` yields the checkpointed restart step."""
    step = 0
    for _attempt in range(max_attempts):
        try:
            step = train_fn(step)
            if step >= total_steps:
                return step
        except WorkerFailure:
            step = restore_fn()
            coordinator.recover()
    raise RuntimeError("exceeded max restart attempts")


class WorkerFailure(RuntimeError):
    pass
