"""Workload executor with partition-aware scheduling (paper §4, §5).

Interprets a traced :class:`~repro.core.dsl.Workload` IR over a
:class:`~repro.data.partition_store.PartitionStore`.  The scheduler decision
the paper cares about happens at every ``partition`` node: if the stored
persistent partitioning *matches* the node's candidate signature (Alg. 4),
the shuffle is **elided** and the downstream join/aggregate runs strictly
worker-locally; otherwise a real repartition (gather + re-bucket) runs and
its cost is measured.

Execution is columnar (numpy host-side — storage-layer compute), with the
per-worker layout carried through so local operators stay local.  Join
restriction: the right side must have unique keys (all paper workloads —
authors, ranks, matrix blocks — satisfy this); documented in DESIGN.md §3.

Backends (DESIGN §5): ``backend="host"`` repartitions with numpy;
``backend="device"`` routes every hash repartition through one cached
single-pass shuffle plan (hash → counting-sort permutation → packed
gather; the fused Pallas kernels on TPU), bit-identical to the host path,
and relays device-resident flats (``TableVal.device_columns``) from scans
of device-backed stores through repartitions into store writes so the
chain never re-uploads payload bytes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .ir import IRGraph, resolve_fn
from .matching import partitioning_match
from .partitioner import PartitionerCandidate, merge, search
from ..data.device_repartition import device_flat_columns, \
    device_rebucket_full
from ..data.partition_store import BACKENDS, PartitionStore, StoredDataset

Columns = Dict[str, np.ndarray]


@dataclass
class TableVal:
    """A set-valued intermediate: flat columns + per-worker segmentation.

    ``device_columns`` is the device-to-device relay (DESIGN §5): flat
    jax-array copies of (a subset of) ``columns`` left on device by a scan
    of a device-backed dataset or by a device repartition.  Row-preserving
    nodes pass it through; the next device stage (repartition, store write)
    consumes it instead of re-uploading the host columns.  Any row-changing
    op (join, aggregate, filter, flatten, map) drops it."""
    columns: Columns
    counts: np.ndarray                       # (m,) rows per worker segment
    partitioner: Optional[PartitionerCandidate] = None   # current layout
    device_columns: Optional[Columns] = None             # flat jax arrays

    @property
    def num_rows(self) -> int:
        return int(self.counts.sum())

    @property
    def m(self) -> int:
        return int(self.counts.shape[0])

    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.counts)[:-1]]).astype(np.int64)

    def worker_slice(self, w: int) -> Columns:
        o = self.offsets()
        return {k: v[o[w]:o[w] + self.counts[w]] for k, v in self.columns.items()}

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.columns.values()))


@dataclass
class EngineStats:
    shuffles_elided: int = 0
    shuffles_performed: int = 0
    shuffle_bytes: int = 0
    device_repartitions: int = 0     # shuffles routed through the Pallas path
    match_overhead_s: float = 0.0
    stage_latency: Dict[str, float] = field(default_factory=dict)
    wall_s: float = 0.0
    shuffle_s: float = 0.0           # wall time spent inside real shuffles
    input_bytes: int = 0             # bytes scanned from the store
    output_bytes: int = 0            # bytes written back to the store
    # per-candidate runtime stats for this run (ExecutionRecord schema),
    # keyed by candidate signature; None unless the run is being observed
    # (history / run hooks attached) — the np.unique pass isn't free.
    candidate_stats: Optional[Dict[str, Dict[str, float]]] = None

    def modeled_network_s(self, bandwidth: float = 1.25e9) -> float:
        return self.shuffle_bytes / bandwidth


class Engine:
    def __init__(self, store: PartitionStore,
                 enable_lachesis_matching: bool = True,
                 net_bandwidth: float = 1.25e9,
                 backend: str = "host",
                 interpret: Optional[bool] = None,
                 history=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.store = store
        self.matching = enable_lachesis_matching
        self.net_bandwidth = net_bandwidth
        self.backend = backend
        self.interpret = interpret   # None → auto (interpret mode off-TPU)
        # observation hooks (DESIGN §8): `history` auto-logs an
        # ExecutionRecord per run; run_hooks fire with (workload, stats)
        # after every run (the service's Observer attaches here).
        self.history = history
        self.run_hooks: List[Callable[[Any, EngineStats], None]] = []

    def add_run_hook(self, fn: Callable[[Any, EngineStats], None]) -> None:
        """Register ``fn(workload, stats)`` to fire after every run."""
        self.run_hooks.append(fn)

    # ------------------------------------------------------------------ run --
    def run(self, workload, backend: Optional[str] = None,
            history=None,
            timestamp: Optional[float] = None
            ) -> Tuple[Dict[int, Any], EngineStats]:
        """Execute ``workload``; returns ``(node values, stats)``.

        With ``history`` (or a constructor-level ``history``) attached, an
        :class:`~repro.core.history.ExecutionRecord` is appended
        automatically — app id, IR signature, latency, input/output bytes
        and per-candidate selectivity/distinct-key stats measured at each
        partition node — closing the paper's observe loop without
        hand-built records.  ``timestamp`` overrides the record's wall
        clock (deterministic tests / logical clocks)."""
        backend = self.backend if backend is None else backend
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        history = self.history if history is None else history
        g: IRGraph = workload.graph
        stats = EngineStats()
        if history is not None or self.run_hooks:
            stats.candidate_stats = {}
        t_start = time.perf_counter()
        vals: Dict[int, Any] = {}
        # Pre-compute candidate subgraphs per partition node (for key
        # evaluation and elision checks).
        cands_by_pnode: Dict[int, PartitionerCandidate] = {}
        for s in g.scans:
            for c in merge(g, search(g, s)):
                cands_by_pnode[c.origin[1]] = c

        for nid in g.toposort():
            node = g.nodes[nid]
            t0 = time.perf_counter()
            kind = node.kind
            parents = g.parents(nid)

            if kind == "scan":
                ds = self.store.read(node.params["dataset"])
                flat = ds.gather()
                dev = device_flat_columns(ds) if backend == "device" else None
                stats.input_bytes += ds.nbytes
                vals[nid] = TableVal(flat, ds.counts.copy(), ds.partitioner,
                                     device_columns=dev)
            elif kind == "partition":
                vals[nid] = self._exec_partition(g, nid, cands_by_pnode,
                                                 vals, stats, backend)
            elif kind == "join":
                vals[nid] = self._exec_join(vals[parents[0]], vals[parents[1]],
                                            node.params.get("projection"))
            elif kind == "aggregate":
                vals[nid] = self._exec_aggregate(vals[parents[0]], node.params)
            elif kind == "apply":
                vals[nid] = self._exec_map(vals[parents[0]], node.params["fn"])
            elif kind == "flatten":
                vals[nid] = self._exec_flatten(vals[parents[0]])
            elif kind == "filter":
                vals[nid] = self._exec_filter(vals[parents[0]], vals[parents[1]])
            elif kind == "write":
                tv: TableVal = vals[parents[0]]
                cols = {k: v for k, v in tv.columns.items()
                        if k != "__key__"}
                self.store.write_layout(node.params["dataset"], cols,
                                        tv.counts, tv.partitioner,
                                        device_columns=tv.device_columns)
                stats.output_bytes += int(sum(v.nbytes for v in cols.values()))
                vals[nid] = tv
            else:
                # lambda nodes: evaluate over parent values (columns/TableVal)
                fn = resolve_fn(node.label, node.params)
                args = [vals[p].columns if isinstance(vals[p], TableVal)
                        else vals[p] for p in parents]
                vals[nid] = fn(*args)
            stats.stage_latency[f"{nid}:{node.label}"] = \
                stats.stage_latency.get(f"{nid}:{node.label}", 0.0) + \
                (time.perf_counter() - t0)

        stats.wall_s = time.perf_counter() - t_start
        if history is not None:
            history.log_workload(
                workload,
                timestamp=time.time() if timestamp is None else timestamp,
                latency=stats.wall_s,
                input_bytes=float(stats.input_bytes),
                output_bytes=float(stats.output_bytes),
                candidate_stats=stats.candidate_stats or {})
        for hook in self.run_hooks:
            hook(workload, stats)
        return vals, stats

    # ------------------------------------------------------- partition node --
    def _exec_partition(self, g, nid, cands_by_pnode, vals, stats,
                        backend: str = "host") -> TableVal:
        """Repartition (or elide) at a partition node.

        The partition key is the *evaluated* parent key-expression — aligned
        with the current table's rows (works for post-join/flatten keys,
        where recompiling the root-scan chain would be wrong).  The
        extracted candidate (when the node is a first-level scan→partition,
        Alg. 1) drives the Alg. 4 elision check against stored layouts."""
        cand = cands_by_pnode.get(nid)
        table: TableVal = _first_table(vals, g, nid)
        key_parent = g.parents(nid)[0]
        key_vals = np.asarray(vals[key_parent]).reshape(-1)

        # observation (DESIGN §8): per-candidate runtime stats measured at
        # this node feed the auto-logged ExecutionRecord
        if stats.candidate_stats is not None and cand is not None:
            _record_candidate_stats(stats.candidate_stats,
                                    cand.signature(), table, key_vals)

        # Alg. 4 elision check against the table's current layout
        if (cand is not None and self.matching
                and table.partitioner is not None):
            t0 = time.perf_counter()
            dataset = g.nodes[cand.origin[0]].params.get("dataset", "")
            m = partitioning_match(table.partitioner, dataset, g)
            stats.match_overhead_s += time.perf_counter() - t0
            if nid in m.partition_nodes:
                stats.shuffles_elided += 1
                out = TableVal(dict(table.columns), table.counts.copy(),
                               table.partitioner,
                               device_columns=table.device_columns)
                out.columns["__key__"] = key_vals
                return out                   # layout already correct

        # shuffle: hash the key column, re-bucket every column
        from .ir import _mix_hash
        strategy = g.nodes[nid].params.get("strategy", "hash")
        t_sh = time.perf_counter()
        if backend == "device" and strategy == "hash" and key_vals.size:
            # DESIGN §5: one jitted plan — fused hash + histogram +
            # counting-sort permutation + packed gather; upstream device
            # flats (scan of a device store) feed it without re-upload
            res = device_rebucket_full(table.columns, key_vals, table.m,
                                       interpret=self.interpret,
                                       device_columns=table.device_columns)
            stats.shuffles_performed += 1
            stats.device_repartitions += 1
            stats.shuffle_bytes += int(table.nbytes() * (table.m - 1)
                                       / table.m)
            stats.shuffle_s += time.perf_counter() - t_sh
            return TableVal(res.columns, res.counts,
                            cand or table.partitioner,
                            device_columns=res.device_columns)
        if strategy == "range":
            lo, hi = key_vals.min(), key_vals.max()
            width = max((hi - lo) / table.m, 1e-9)
            pids = np.clip(((key_vals - lo) / width).astype(np.int64),
                           0, table.m - 1)
        else:
            pids = np.asarray(_mix_hash(key_vals)).astype(np.int64) % table.m
        order = np.argsort(pids, kind="stable")
        counts = np.bincount(pids, minlength=table.m).astype(np.int64)
        new_cols = {k: v[order] for k, v in table.columns.items()}
        new_cols["__key__"] = key_vals[order]
        stats.shuffles_performed += 1
        stats.shuffle_bytes += int(table.nbytes() * (table.m - 1) / table.m)
        stats.shuffle_s += time.perf_counter() - t_sh
        return TableVal(new_cols, counts, cand or table.partitioner)

    # ------------------------------------------------------------- join node --
    def _exec_join(self, left: TableVal, right: TableVal,
                   projection: Optional[Callable]) -> TableVal:
        out_segments: List[Columns] = []
        counts = np.zeros(left.m, np.int64)
        for w in range(left.m):
            lc, rc = left.worker_slice(w), right.worker_slice(w)
            lk = lc.pop("__key__")
            rk = rc.pop("__key__")
            if lk.size == 0 or rk.size == 0:
                continue
            sidx = np.argsort(rk, kind="stable")
            rk_sorted = rk[sidx]
            pos = np.searchsorted(rk_sorted, lk)
            pos = np.clip(pos, 0, rk_sorted.size - 1)
            hit = rk_sorted[pos] == lk
            ridx = sidx[pos[hit]]
            lsel = np.nonzero(hit)[0]
            seg: Columns = {k: v[lsel] for k, v in lc.items()}
            for k, v in rc.items():
                seg[f"r_{k}" if k in seg else k] = v[ridx]
            if projection is not None:
                seg = projection(seg)
            counts[w] = len(lsel)
            out_segments.append(seg)
        if out_segments:
            keys = out_segments[0].keys()
            cols = {k: np.concatenate([s[k] for s in out_segments])
                    for k in keys}
        else:
            cols = {}
        return TableVal(cols, counts, left.partitioner)

    # -------------------------------------------------------- aggregate node --
    def _exec_aggregate(self, table: TableVal, params) -> TableVal:
        reducer = params.get("reducer", "sum")
        fn = params.get("fn")
        if fn is not None:
            return TableVal(fn(table.columns), np.array([1] * table.m),
                            table.partitioner)
        # keyed aggregation: key is the repartition key from the upstream
        # partition node ("__key__"); values are all other columns
        out_segs: List[Columns] = []
        counts = np.zeros(table.m, np.int64)
        for w in range(table.m):
            seg = table.worker_slice(w)
            if not seg or len(next(iter(seg.values()))) == 0:
                continue
            key = seg.get("__key__", seg.get("key"))
            uk, inv = np.unique(key, return_inverse=True)
            agg: Columns = {"key": uk}
            for k, v in seg.items():
                if k in ("key", "__key__"):
                    continue
                acc = np.zeros((len(uk),) + v.shape[1:], np.float64)
                np.add.at(acc, inv, v)
                if reducer == "mean":
                    cnt = np.bincount(inv, minlength=len(uk)).astype(np.float64)
                    acc = acc / cnt.reshape((-1,) + (1,) * (acc.ndim - 1))
                agg[k] = acc.astype(v.dtype)
            counts[w] = len(uk)
            out_segs.append(agg)
        if out_segs:
            cols = {k: np.concatenate([s[k] for s in out_segs])
                    for k in out_segs[0]}
        else:
            cols = {}
        return TableVal(cols, counts, table.partitioner)

    # ------------------------------------------------------------- map/flatten --
    def _exec_map(self, table: TableVal, fn: Optional[Callable]) -> TableVal:
        if fn is None:
            return table
        return TableVal(fn(table.columns), table.counts.copy(),
                        table.partitioner)

    def _exec_flatten(self, table: TableVal) -> TableVal:
        fan = None
        cols: Columns = {}
        for k, v in table.columns.items():
            if v.ndim >= 2:
                fan = v.shape[1]
                cols[k] = v.reshape((-1,) + v.shape[2:])
        if fan is None:
            return table
        for k, v in table.columns.items():
            if v.ndim == 1:
                cols[k] = np.repeat(v, fan)
        return TableVal(cols, table.counts * fan, table.partitioner)

    def _exec_filter(self, table: TableVal, pred: np.ndarray) -> TableVal:
        pred = np.asarray(pred).reshape(-1).astype(bool)
        o = table.offsets()
        counts = np.array([int(pred[o[w]:o[w] + table.counts[w]].sum())
                           for w in range(table.m)], np.int64)
        cols = {k: v[pred] for k, v in table.columns.items()}
        return TableVal(cols, counts, table.partitioner)


def _record_candidate_stats(out: Dict[str, Dict[str, float]], sig: str,
                            table: TableVal, key_vals: np.ndarray) -> None:
    """Measure the ExecutionRecord candidate-stat schema at a partition
    node.  Two partition nodes in one run can share a (structural)
    signature; merging mirrors features.py aggregation — max selectivity,
    min distinct keys — so per-run stats compose like per-group ones."""
    object_bytes = float(table.nbytes())
    key_bytes = float(key_vals.nbytes)
    st = {
        "selectivity": key_bytes / object_bytes if object_bytes else 0.0,
        "distinct_keys": float(np.unique(key_vals).size),
        "num_objects": float(table.num_rows),
        "key_bytes": key_bytes,
        "object_bytes": object_bytes,
    }
    cur = out.get(sig)
    if cur is None:
        out[sig] = st
        return
    for k, v in st.items():
        cur[k] = min(cur[k], v) if k == "distinct_keys" else max(cur[k], v)


def _first_table(vals, g, nid):
    for p in g.parents(nid):
        v = vals.get(p)
        if isinstance(v, TableVal):
            return v
        sub = _first_table(vals, g, p)
        if sub is not None:
            return sub
    return None
