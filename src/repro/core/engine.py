"""Engine — the legacy eager entry point, now a deprecation shim.

Historically this module *was* the execution surface: ``Engine.run``
interpreted the traced IR node-by-node, re-extracting partitioner
candidates and re-running Alg. 4 on every run.  The planner/executor
split (DESIGN §9) moved that policy into
:class:`~repro.core.planner.Planner` (Workload → LogicalPlan →
PhysicalPlan, cached by IR signature × store layout generation) and the
mechanics into :class:`~repro.core.executor.Executor`; the public facade
is :class:`repro.api.Session` (aka ``lachesis.Session``).

``Engine`` remains as a thin shim so existing call sites keep working
bit-identically — it plans through the same cache and executes the same
steps — but every ``Engine.run`` emits a :class:`DeprecationWarning`.
Migration is mechanical::

    eng = Engine(store, backend="device")      # before
    vals, stats = eng.run(wl)

    sess = Session(store, backend="device")    # after
    res = sess.run(wl)                         # res.values, res.stats
    vals, stats = sess.run(wl)                 # tuple-unpacking still works

``TableVal`` and ``EngineStats`` are re-exported from
:mod:`repro.core.executor`, their new home.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

from .backends import UnknownBackendError, resolve_backend  # noqa: F401
from .executor import (EngineStats, Executor, StalePlanError,  # noqa: F401
                       TableVal, plan_and_execute)
from .planner import Planner

__all__ = ["Engine", "EngineStats", "TableVal", "StalePlanError",
           "UnknownBackendError"]


class Engine:
    """Deprecated facade over ``Planner`` + ``Executor``.

    Prefer :class:`repro.api.Session`; this shim exists so pre-split call
    sites (and their tests) keep passing unchanged.
    """

    def __init__(self, store, enable_lachesis_matching: bool = True,
                 net_bandwidth: float = 1.25e9,
                 backend: str = "host",
                 interpret: Optional[bool] = None,
                 history=None):
        self.backend = resolve_backend(backend).name   # UnknownBackendError
        self.net_bandwidth = net_bandwidth
        # observation hooks (DESIGN §8): `history` auto-logs an
        # ExecutionRecord per run; run_hooks fire with (workload, stats)
        # after every run (the service's Observer attaches here).
        self.history = history
        self.run_hooks: List[Callable[[Any, EngineStats], None]] = []
        # the same planning/execution stack Session uses
        self.planner = Planner(store, matching=enable_lachesis_matching)
        self.executor = Executor(store, interpret=interpret)

    # mutable knobs forward into the planner/executor so the historical
    # `eng.matching = False` / `eng.interpret = True` idioms keep working
    @property
    def store(self):
        return self.planner.store

    @property
    def matching(self) -> bool:
        return self.planner.matching

    @matching.setter
    def matching(self, v: bool) -> None:
        self.planner.matching = bool(v)

    @property
    def interpret(self) -> Optional[bool]:
        return self.executor.interpret

    @interpret.setter
    def interpret(self, v: Optional[bool]) -> None:
        self.executor.interpret = v

    def add_run_hook(self, fn: Callable[[Any, EngineStats], None]) -> None:
        """Register ``fn(workload, stats)`` to fire after every run."""
        self.run_hooks.append(fn)

    # ------------------------------------------------------------------ run --
    def run(self, workload, backend: Optional[str] = None,
            history=None,
            timestamp: Optional[float] = None
            ) -> Tuple[Dict[int, Any], EngineStats]:
        """Deprecated: plan + execute in one call (use ``Session.run``).

        Semantics are unchanged from the eager interpreter: same values,
        same stats schema, history/hook observation identical — but the
        run now goes through the PhysicalPlan cache, so repeated runs of
        a frozen workload skip candidate extraction and Alg. 4 entirely.
        """
        warnings.warn(
            "Engine.run is deprecated; use lachesis.Session "
            "(repro.api.Session) — session.run(workload) returns the same "
            "(values, stats) and adds plan caching and explain()",
            DeprecationWarning, stacklevel=2)
        backend = self.backend if backend is None else \
            resolve_backend(backend).name
        history = self.history if history is None else history
        vals, stats, _plan = plan_and_execute(
            self.planner, self.executor, workload, backend,
            history=history, hooks=tuple(self.run_hooks),
            timestamp=timestamp)
        return vals, stats
