# Lachesis core: the paper's primary contribution.
#   ir, dsl          — analyzable/executable graph IR for UDF workloads
#   partitioner      — two-terminal candidate extraction (Alg. 1+2)
#   matching         — path-signature subgraph matching (Alg. 4)
#   history          — workflow analyzer + skeleton graph (§3.1.1)
#   features         — candidate state vector (§3.1.3)
#   advisor          — end-to-end partitioning_creation (Alg. 3)
#   backends         — capability-queried backend registry (DESIGN §9)
#   planner          — Workload → LogicalPlan → PhysicalPlan + plan cache
#   executor         — runs frozen PhysicalPlans (§4 semantics)
#   engine           — legacy eager facade, now a deprecation shim
#   drl              — actor-critic selector + trace simulator (§3.1.3, §4.3)
#   sharding_bridge  — partitionings ⇄ JAX NamedShardings (TPU adaptation)

from .ir import IRGraph, Node
from .dsl import Workload, author_integrator, pagerank_iteration, matmul_workload
from .partitioner import (PartitionerCandidate, enumerate_candidates,
                          keyless_candidates, search, merge, dedupe,
                          HASH, RANGE, ROUND_ROBIN, RANDOM)
from .matching import partitioning_match, plan_shuffles, MatchResult
from .history import HistoryStore, ExecutionRecord, SkeletonNode
from .features import candidate_features, build_state, state_dim
from .advisor import (partitioning_creation, apply_decision,
                      PartitioningDecision, GreedySelector, DRLSelector)
from .backends import (Backend, BackendRegistry, REGISTRY,
                       UnknownBackendError, resolve_backend)
from .planner import LogicalPlan, PhysicalPlan, PlanKey, PlanStep, Planner
from .executor import Executor, StalePlanError
from .engine import Engine, EngineStats, TableVal
