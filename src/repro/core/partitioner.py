"""Partitioner candidates: two-terminal DAG extraction (paper §3.1.2).

Alg. 1 (``search``) enumerates all simple paths from the dataset's scan node
to any partition node.  Alg. 2 (``merge``) merges paths sharing the same
(root, leaf) pair into one candidate subgraph.  A candidate is executable:
:meth:`PartitionerCandidate.key_fn` recompiles the subgraph into a jittable
key projection — the paper's Listing 2 extracted from Listing 1.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .ir import IRGraph, _mix_hash

HASH = "hash"
RANGE = "range"
ROUND_ROBIN = "roundrobin"
RANDOM = "random"
KEYED_STRATEGIES = (HASH, RANGE)
KEYLESS_STRATEGIES = (ROUND_ROBIN, RANDOM)


# ---------------------------------------------------------------------------
# Alg. 1: search(a_i, s_D) — all scan→partition simple paths
# ---------------------------------------------------------------------------

def search(graph: IRGraph, s_D: int) -> List[List[int]]:
    """Enumerate all simple paths that start at scan node ``s_D`` and end at
    the *first* partition node encountered (paper Alg. 1: recursion stops
    when v_k is a partition node)."""
    paths: List[List[int]] = []
    stack: List[Tuple[int, List[int]]] = [(s_D, [s_D])]
    while stack:
        node, path = stack.pop()
        for child in graph.children(node):
            if child in path:
                continue
            new_path = path + [child]
            if graph.nodes[child].is_partition:
                if len(new_path) > 1:
                    paths.append(new_path)
            else:
                stack.append((child, new_path))
    return paths


# ---------------------------------------------------------------------------
# Alg. 2: merge(F_i) — union paths by (root, leaf)
# ---------------------------------------------------------------------------

def merge(graph: IRGraph, paths: Sequence[Sequence[int]]) -> List["PartitionerCandidate"]:
    buckets: Dict[Tuple[int, int], Dict[str, set]] = {}
    for p in paths:
        key = (p[0], p[-1])
        b = buckets.setdefault(key, {"nodes": set(), "edges": set()})
        b["nodes"].update(p)
        b["edges"].update(zip(p[:-1], p[1:]))
    out: List[PartitionerCandidate] = []
    for (root, leaf), b in sorted(buckets.items()):
        sub = graph.subgraph(sorted(b["nodes"]))
        strategy = graph.nodes[leaf].params.get("strategy", HASH)
        out.append(PartitionerCandidate(
            graph=sub,
            strategy=strategy,
            source_dataset=graph.nodes[root].params.get("dataset", ""),
            origin=(root, leaf),
        ))
    return out


def enumerate_candidates(graph: IRGraph, dataset: str) -> List["PartitionerCandidate"]:
    """merge(search(h(w_i)), D) for one workload IR (paper §3.1.2)."""
    s_D = graph.find_scanner(dataset)
    if s_D is None:
        return []
    return merge(graph, search(graph, s_D))


# ---------------------------------------------------------------------------
# Candidates
# ---------------------------------------------------------------------------

@dataclass
class PartitionerCandidate:
    """A two-terminal subgraph + strategy; ``f_D`` in the paper."""

    graph: Optional[IRGraph]          # None for keyless strategies
    strategy: str = HASH
    source_dataset: str = ""
    origin: Tuple[int, int] = (-1, -1)  # (root, leaf) ids in the parent IR

    #: True when ``partition_ids`` is exactly hash(key) % m, so the device
    #: hash kernel may compute pids from the key column alone.  Subclasses
    #: with custom pid math (e.g. SaltedPartitioner) set this False and the
    #: store falls back to host pids + device scatter.
    kernel_dispatchable = True

    def __post_init__(self):
        if self.graph is not None and not self.graph.is_two_terminal():
            raise ValueError("partitioner candidate must be two-terminal")

    # -- identity -----------------------------------------------------------
    def signature_set(self) -> Tuple[str, ...]:
        """Sorted set of root→leaf path signatures (``ssset_D`` in Alg. 4)."""
        if self.graph is None:
            return (self.strategy,)
        (root,), (leaf,) = self.graph.roots(), self.graph.leaves()
        return tuple(self.graph.path_signatures(root, leaf))

    def signature(self) -> str:
        return "|".join(self.signature_set())

    @property
    def is_keyed(self) -> bool:
        return self.strategy in KEYED_STRATEGIES

    # -- executability --------------------------------------------------------
    def key_fn(self) -> Callable:
        if self.graph is None:
            raise ValueError(f"{self.strategy} partitioner has no key fn")
        return self.graph.compile_fn()

    def complexity(self) -> int:
        """Weight sum along the shortest root→leaf path (feature #4)."""
        if self.graph is None:
            return 0
        (root,), (leaf,) = self.graph.roots(), self.graph.leaves()
        paths = self.graph.all_paths(root, leaf)
        weights = {"parse": 5, "opaque": 3, "func": 2, "binop": 1, "attr": 1,
                   "literal": 0, "scan": 0, "partition": 0, "index": 1,
                   "cond": 1}
        def w(p):
            return sum(weights.get(self.graph.nodes[n].kind, 1) for n in p)
        return min(w(p) for p in paths)

    # -- application ------------------------------------------------------------
    def partition_ids(self, data: Any, num_partitions: int,
                      rng: Optional[jax.Array] = None) -> jax.Array:
        """Map each object to a partition id — ``g(d_i)`` per §2.2.2."""
        if self.strategy == HASH:
            key = self.key_fn()(data)
            return (_mix_hash(key) % jnp.uint32(num_partitions)).astype(jnp.int32)
        if self.strategy == RANGE:
            key = jnp.asarray(self.key_fn()(data))
            # range(k): quantile binning against the observed key range
            lo, hi = key.min(), key.max()
            width = jnp.maximum((hi - lo) / num_partitions, 1e-9)
            return jnp.clip(((key - lo) / width).astype(jnp.int32),
                            0, num_partitions - 1)
        n = _num_objects(data)
        if self.strategy == ROUND_ROBIN:
            return (jnp.arange(n) % num_partitions).astype(jnp.int32)
        if self.strategy == RANDOM:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            return jax.random.randint(rng, (n,), 0, num_partitions, jnp.int32)
        raise ValueError(f"unknown strategy {self.strategy}")


@dataclass
class SaltedPartitioner(PartitionerCandidate):
    """Hot-key splitting (DESIGN §12): rows of a *hot* key are sprayed
    round-robin across ``salt_factor`` consecutive partitions instead of
    all landing on ``hash(key) % m``, so one heavy hitter stops dictating
    every partition's capacity.

    Correctness composes automatically: the salt is part of
    ``signature_set()``, so Alg. 4 never equates a salted layout with a
    consumer's plain hash partitioner — consumers shuffle (no wrong
    elision), and the Autopilot only applies salting when the padding
    savings outweigh the elision it forfeits (priced by the cost model).
    """

    hot_keys: Tuple = ()
    salt_factor: int = 4

    kernel_dispatchable = False     # pid math below ≠ plain hash(key) % m

    def signature_set(self) -> Tuple[str, ...]:
        base = super().signature_set()
        keys = ",".join(str(k) for k in self.hot_keys)
        return tuple(f"salt{self.salt_factor}[{keys}]({s})" for s in base)

    def partition_ids(self, data: Any, num_partitions: int,
                      rng: Optional[jax.Array] = None) -> Any:
        import numpy as np
        keys = np.asarray(self.key_fn()(data)).reshape(-1)
        base = np.asarray(
            super().partition_ids(data, num_partitions)).astype(np.int64)
        hot = np.isin(keys, np.asarray(list(self.hot_keys),
                                       dtype=keys.dtype))
        salt = np.arange(keys.shape[0], dtype=np.int64) % self.salt_factor
        return np.where(hot, (base + salt) % num_partitions,
                        base).astype(np.int32)


def keyless_candidates() -> List[PartitionerCandidate]:
    """Round-robin and random are always in the action space (§3.1.3)."""
    return [PartitionerCandidate(graph=None, strategy=ROUND_ROBIN),
            PartitionerCandidate(graph=None, strategy=RANDOM)]


def _num_objects(data: Any) -> int:
    if isinstance(data, dict):
        data = next(iter(data.values()))
    return int(jnp.shape(data)[0])


# ---------------------------------------------------------------------------
# Deduplication across consuming workloads (advisor-level)
# ---------------------------------------------------------------------------

def dedupe(cands: Sequence[PartitionerCandidate]) -> List[PartitionerCandidate]:
    seen: Dict[str, PartitionerCandidate] = {}
    for c in cands:
        seen.setdefault(c.signature(), c)
    return list(seen.values())
