"""Historical workflow analyzer (paper §3.1.1, §4.2).

Reconstructs the low-level workflow graph from execution logs (node =
(app_id, timestamp) execution, edge = dataset produced by src and consumed
by dst), condenses it into a *skeleton graph* by merging executions whose IR
signatures are equal, and answers the workload-enumeration query: given a
producer about to write a dataset, which historical workloads will likely
consume it?
"""

from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .ir import IRGraph


@dataclass
class ExecutionRecord:
    """One execution of a workload (one node of the low-level graph).

    ``weight`` is the number of real executions this record stands for: 1
    for a live run, >1 for an aggregate produced by :meth:`HistoryStore.
    compact` (latency/bytes then hold the weighted means of the merged
    runs, ``timestamp`` their most recent)."""
    app_id: str
    timestamp: float
    ir_signature: str
    inputs: List[str] = field(default_factory=list)    # dataset ids read
    outputs: List[str] = field(default_factory=list)   # dataset ids written
    latency: float = 0.0                               # seconds
    input_bytes: float = 0.0
    output_bytes: float = 0.0
    # padded-layout accounting over the datasets this run scanned (DESIGN
    # §12): the padded-vs-valid gap feeds the cost model's padding term
    padded_bytes: float = 0.0
    valid_bytes: float = 0.0
    # per-candidate runtime stats observed in this run, keyed by candidate
    # signature: {"selectivity": float, "distinct_keys": float,
    #             "key_bytes": float, "object_bytes": float}
    candidate_stats: Dict[str, Dict[str, float]] = field(default_factory=dict)
    weight: float = 1.0


@dataclass
class SkeletonNode:
    """A group of executions sharing one IR signature (Fig. 3b)."""
    group_id: int
    ir_signature: str
    runs: List[ExecutionRecord] = field(default_factory=list)

    @property
    def app_ids(self) -> Set[str]:
        return {r.app_id for r in self.runs}


class HistoryStore:
    """Append-only execution log + derived graphs.

    The store optionally persists to a JSONL file so history survives process
    restarts (the paper's write-once/read-many premise needs durability).
    """

    def __init__(self, path: Optional[str] = None):
        self.records: List[ExecutionRecord] = []
        self.irs: Dict[str, IRGraph] = {}          # ir_signature -> IR graph
        self.path = path
        self._lock = threading.Lock()   # appends vs compaction (service)
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    self.records.append(ExecutionRecord(**json.loads(line)))

    # -- logging ----------------------------------------------------------------
    def log(self, record: ExecutionRecord, ir: Optional[IRGraph] = None) -> None:
        with self._lock:
            self.records.append(record)
            if ir is not None:
                self.irs[record.ir_signature] = ir
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(asdict(record)) + "\n")

    def log_workload(self, workload, *, timestamp: float, latency: float = 0.0,
                     input_bytes: float = 0.0, output_bytes: float = 0.0,
                     padded_bytes: float = 0.0, valid_bytes: float = 0.0,
                     candidate_stats: Optional[Dict] = None) -> ExecutionRecord:
        g = workload.graph
        rec = ExecutionRecord(
            app_id=workload.app_id, timestamp=timestamp,
            ir_signature=g.graph_signature(),
            inputs=[g.nodes[s].params["dataset"] for s in g.scans],
            outputs=[g.nodes[o].params["dataset"] for o in g.writes],
            latency=latency, input_bytes=input_bytes,
            output_bytes=output_bytes,
            padded_bytes=padded_bytes, valid_bytes=valid_bytes,
            candidate_stats=candidate_stats or {})
        self.log(rec, ir=g)
        return rec

    # -- low-level workflow graph (Fig. 3a) -----------------------------------------
    def low_level_graph(self) -> List[Tuple[int, int, str]]:
        """Edges (producer_idx, consumer_idx, dataset) between executions."""
        edges = []
        producers: Dict[str, List[int]] = {}
        for i, r in enumerate(self.records):
            for d in r.outputs:
                producers.setdefault(d, []).append(i)
        for j, r in enumerate(self.records):
            for d in r.inputs:
                for i in producers.get(d, []):
                    # producer must precede the consumer
                    if self.records[i].timestamp <= r.timestamp and i != j:
                        edges.append((i, j, d))
        return edges

    # -- skeleton graph (Fig. 3b) -----------------------------------------------------
    def skeleton_graph(self) -> Tuple[Dict[str, SkeletonNode],
                                      Set[Tuple[str, str]]]:
        groups: Dict[str, SkeletonNode] = {}
        for r in self.records:
            if r.ir_signature not in groups:
                groups[r.ir_signature] = SkeletonNode(len(groups), r.ir_signature)
            groups[r.ir_signature].runs.append(r)
        edges: Set[Tuple[str, str]] = set()
        idx = {i: r.ir_signature for i, r in enumerate(self.records)}
        for i, j, _d in self.low_level_graph():
            edges.add((idx[i], idx[j]))
        return groups, edges

    # -- workload enumeration (§3.1.1) ---------------------------------------------------
    def enumerate_consumers(self, producer_signature: str) -> List[SkeletonNode]:
        """Workloads W that historically consumed outputs of executions whose
        IR signature matches the producer's — the future-consumer prediction."""
        groups, edges = self.skeleton_graph()
        if producer_signature not in groups:
            return []
        out = [groups[dst] for (src, dst) in edges
               if src == producer_signature and dst in groups]
        # dedupe, stable order by group id
        seen, uniq = set(), []
        for g in out:
            if g.group_id not in seen:
                seen.add(g.group_id)
                uniq.append(g)
        return sorted(uniq, key=lambda g: g.group_id)

    def ir_of(self, signature: str) -> Optional[IRGraph]:
        return self.irs.get(signature)

    # -- compaction (bounds the append-only log) --------------------------------
    def compact(self, max_records: int) -> int:
        """Bound the log: keep the newest ``max_records`` records verbatim
        and merge everything older into one aggregate record per skeleton
        group (IR signature), preserving weighted means, total weight and
        the most recent timestamp.  Returns the number of records removed.

        Post-compaction size is ``max_records + (#distinct old skeletons)``
        — bounded by the (small, stable) skeleton count, so a service
        appending every run can compact periodically and the log never
        grows without limit.  When the store is file-backed the JSONL is
        atomically rewritten (tmp + rename)."""
        with self._lock:
            if max_records < 0:
                raise ValueError("max_records must be >= 0")
            if len(self.records) <= max_records:
                return 0
            cut = len(self.records) - max_records
            old, keep = self.records[:cut], self.records[cut:]
            merged: Dict[str, ExecutionRecord] = {}
            order: List[str] = []
            for r in old:
                agg = merged.get(r.ir_signature)
                if agg is None:
                    merged[r.ir_signature] = _copy_record(r)
                    order.append(r.ir_signature)
                else:
                    _merge_record(agg, r)
            self.records = [merged[s] for s in order] + keep
            removed = cut - len(merged)
            if self.path:
                tmp = self.path + ".tmp"
                with open(tmp, "w") as f:
                    for r in self.records:
                        f.write(json.dumps(asdict(r)) + "\n")
                os.replace(tmp, self.path)
            return removed

    # -- simple aggregates used by features.py ----------------------------------------------
    def runs_of_group(self, signature: str) -> List[ExecutionRecord]:
        return [r for r in self.records if r.ir_signature == signature]

    def total_runs(self) -> float:
        """Number of executions represented (compaction-aware)."""
        return float(sum(r.weight for r in self.records))

    def overall_throughput(self) -> float:
        """Baseline throughput (bytes/s) over all history — reward denominator."""
        total_bytes = sum(r.weight * r.input_bytes for r in self.records)
        total_lat = sum(r.weight * r.latency for r in self.records)
        return total_bytes / total_lat if total_lat > 0 else 0.0


def _copy_record(r: ExecutionRecord) -> ExecutionRecord:
    return ExecutionRecord(
        app_id=r.app_id, timestamp=r.timestamp, ir_signature=r.ir_signature,
        inputs=list(r.inputs), outputs=list(r.outputs), latency=r.latency,
        input_bytes=r.input_bytes, output_bytes=r.output_bytes,
        padded_bytes=r.padded_bytes, valid_bytes=r.valid_bytes,
        candidate_stats={k: dict(v) for k, v in r.candidate_stats.items()},
        weight=r.weight)


def _merge_record(agg: ExecutionRecord, r: ExecutionRecord) -> None:
    """Fold ``r`` into the aggregate ``agg`` (same IR signature).

    Scalars become weighted means; per-candidate stats follow the feature
    aggregation semantics of features.py (max selectivity, min distinct
    keys) so max/min over the compacted log equal max/min over the raw
    runs it replaced."""
    w = agg.weight + r.weight
    agg.latency = (agg.weight * agg.latency + r.weight * r.latency) / w
    agg.input_bytes = (agg.weight * agg.input_bytes
                       + r.weight * r.input_bytes) / w
    agg.output_bytes = (agg.weight * agg.output_bytes
                        + r.weight * r.output_bytes) / w
    agg.padded_bytes = (agg.weight * agg.padded_bytes
                        + r.weight * r.padded_bytes) / w
    agg.valid_bytes = (agg.weight * agg.valid_bytes
                       + r.weight * r.valid_bytes) / w
    agg.timestamp = max(agg.timestamp, r.timestamp)
    for d in r.inputs:
        if d not in agg.inputs:
            agg.inputs.append(d)
    for d in r.outputs:
        if d not in agg.outputs:
            agg.outputs.append(d)
    for sig, st in r.candidate_stats.items():
        cur = agg.candidate_stats.setdefault(sig, dict(st))
        if cur is not st:
            for k, v in st.items():
                if k == "distinct_keys" and k in cur:
                    cur[k] = min(cur[k], v)
                elif k in cur:
                    cur[k] = max(cur[k], v)
                else:
                    cur[k] = v
    agg.weight = w
