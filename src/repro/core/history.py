"""Historical workflow analyzer (paper §3.1.1, §4.2).

Reconstructs the low-level workflow graph from execution logs (node =
(app_id, timestamp) execution, edge = dataset produced by src and consumed
by dst), condenses it into a *skeleton graph* by merging executions whose IR
signatures are equal, and answers the workload-enumeration query: given a
producer about to write a dataset, which historical workloads will likely
consume it?
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .ir import IRGraph


@dataclass
class ExecutionRecord:
    """One execution of a workload (one node of the low-level graph)."""
    app_id: str
    timestamp: float
    ir_signature: str
    inputs: List[str] = field(default_factory=list)    # dataset ids read
    outputs: List[str] = field(default_factory=list)   # dataset ids written
    latency: float = 0.0                               # seconds
    input_bytes: float = 0.0
    output_bytes: float = 0.0
    # per-candidate runtime stats observed in this run, keyed by candidate
    # signature: {"selectivity": float, "distinct_keys": float,
    #             "key_bytes": float, "object_bytes": float}
    candidate_stats: Dict[str, Dict[str, float]] = field(default_factory=dict)


@dataclass
class SkeletonNode:
    """A group of executions sharing one IR signature (Fig. 3b)."""
    group_id: int
    ir_signature: str
    runs: List[ExecutionRecord] = field(default_factory=list)

    @property
    def app_ids(self) -> Set[str]:
        return {r.app_id for r in self.runs}


class HistoryStore:
    """Append-only execution log + derived graphs.

    The store optionally persists to a JSONL file so history survives process
    restarts (the paper's write-once/read-many premise needs durability).
    """

    def __init__(self, path: Optional[str] = None):
        self.records: List[ExecutionRecord] = []
        self.irs: Dict[str, IRGraph] = {}          # ir_signature -> IR graph
        self.path = path
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    self.records.append(ExecutionRecord(**json.loads(line)))

    # -- logging ----------------------------------------------------------------
    def log(self, record: ExecutionRecord, ir: Optional[IRGraph] = None) -> None:
        self.records.append(record)
        if ir is not None:
            self.irs[record.ir_signature] = ir
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(asdict(record)) + "\n")

    def log_workload(self, workload, *, timestamp: float, latency: float = 0.0,
                     input_bytes: float = 0.0, output_bytes: float = 0.0,
                     candidate_stats: Optional[Dict] = None) -> ExecutionRecord:
        g = workload.graph
        rec = ExecutionRecord(
            app_id=workload.app_id, timestamp=timestamp,
            ir_signature=g.graph_signature(),
            inputs=[g.nodes[s].params["dataset"] for s in g.scans],
            outputs=[g.nodes[o].params["dataset"] for o in g.writes],
            latency=latency, input_bytes=input_bytes,
            output_bytes=output_bytes,
            candidate_stats=candidate_stats or {})
        self.log(rec, ir=g)
        return rec

    # -- low-level workflow graph (Fig. 3a) -----------------------------------------
    def low_level_graph(self) -> List[Tuple[int, int, str]]:
        """Edges (producer_idx, consumer_idx, dataset) between executions."""
        edges = []
        producers: Dict[str, List[int]] = {}
        for i, r in enumerate(self.records):
            for d in r.outputs:
                producers.setdefault(d, []).append(i)
        for j, r in enumerate(self.records):
            for d in r.inputs:
                for i in producers.get(d, []):
                    # producer must precede the consumer
                    if self.records[i].timestamp <= r.timestamp and i != j:
                        edges.append((i, j, d))
        return edges

    # -- skeleton graph (Fig. 3b) -----------------------------------------------------
    def skeleton_graph(self) -> Tuple[Dict[str, SkeletonNode],
                                      Set[Tuple[str, str]]]:
        groups: Dict[str, SkeletonNode] = {}
        for r in self.records:
            if r.ir_signature not in groups:
                groups[r.ir_signature] = SkeletonNode(len(groups), r.ir_signature)
            groups[r.ir_signature].runs.append(r)
        edges: Set[Tuple[str, str]] = set()
        idx = {i: r.ir_signature for i, r in enumerate(self.records)}
        for i, j, _d in self.low_level_graph():
            edges.add((idx[i], idx[j]))
        return groups, edges

    # -- workload enumeration (§3.1.1) ---------------------------------------------------
    def enumerate_consumers(self, producer_signature: str) -> List[SkeletonNode]:
        """Workloads W that historically consumed outputs of executions whose
        IR signature matches the producer's — the future-consumer prediction."""
        groups, edges = self.skeleton_graph()
        if producer_signature not in groups:
            return []
        out = [groups[dst] for (src, dst) in edges
               if src == producer_signature and dst in groups]
        # dedupe, stable order by group id
        seen, uniq = set(), []
        for g in out:
            if g.group_id not in seen:
                seen.add(g.group_id)
                uniq.append(g)
        return sorted(uniq, key=lambda g: g.group_id)

    def ir_of(self, signature: str) -> Optional[IRGraph]:
        return self.irs.get(signature)

    # -- simple aggregates used by features.py ----------------------------------------------
    def runs_of_group(self, signature: str) -> List[ExecutionRecord]:
        return [r for r in self.records if r.ir_signature == signature]

    def overall_throughput(self) -> float:
        """Baseline throughput (bytes/s) over all history — reward denominator."""
        total_bytes = sum(r.input_bytes for r in self.records)
        total_lat = sum(r.latency for r in self.records)
        return total_bytes / total_lat if total_lat > 0 else 0.0
