"""End-to-end partitioning creation (paper Alg. 3) + selector policies.

``partitioning_creation`` wires together: workload enumeration (history
skeleton graph) → candidate enumeration (Alg. 1+2 per consumer IR) →
feature extraction → selection (DRL agent or greedy Eq. 2 cost model) →
a :class:`PartitioningDecision` the storage layer applies at write time.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .features import CandidateFeatures, build_state, candidate_features, state_dim
from .history import HistoryStore, SkeletonNode
from .partitioner import (PartitionerCandidate, dedupe, enumerate_candidates,
                          keyless_candidates)


@dataclass
class PartitioningDecision:
    dataset: str
    candidate: PartitionerCandidate
    features: List[CandidateFeatures]
    consumers: List[str]                 # skeleton group signatures
    action_index: int
    state: np.ndarray
    elapsed_s: float                     # advisor online overhead (producer side)


class GreedySelector:
    """Eq. 2 baseline: pick argmin of estimated producer + Σ freq·latency.

    Latency estimate per consumer group: historical mean latency, minus the
    modeled shuffle time when the candidate matches that group's desired
    partitioner (selectivity × input bytes over net bandwidth)."""

    def __init__(self, net_bandwidth: float = 1.25e9,
                 partition_overhead: float = 0.10):
        self.net_bandwidth = net_bandwidth
        self.partition_overhead = partition_overhead

    def select(self, feats: Sequence[CandidateFeatures],
               groups: Sequence[SkeletonNode], dataset_bytes: float,
               state: np.ndarray) -> int:
        best, best_cost = 0, float("inf")
        for i, f in enumerate(feats):
            cand = f.candidate
            producer = dataset_bytes / self.net_bandwidth * \
                (self.partition_overhead if cand.is_keyed else 0.0)
            consumer = 0.0
            for g in groups:
                runs = g.runs
                if not runs:
                    continue
                # weight-aware (compacted records stand for `weight` runs)
                wsum = float(sum(r.weight for r in runs))
                mean_lat = float(sum(r.weight * r.latency
                                     for r in runs)) / wsum
                freq = wsum
                saved = 0.0
                if cand.is_keyed and any(
                        cand.signature() in r.candidate_stats for r in runs):
                    # an avoided shuffle moves ~the whole dataset once per
                    # consumer run (Eq. 2's freq_k × lat_k delta)
                    saved = min(mean_lat * 0.9,
                                dataset_bytes / self.net_bandwidth)
                consumer += freq * (mean_lat - saved)
            cost = producer + consumer
            if cost < best_cost:
                best, best_cost = i, cost
        return best


class DRLSelector:
    """Wraps an :class:`~repro.core.drl.agent.A3CAgent` (paper §3.1.3)."""

    def __init__(self, agent, greedy: bool = True):
        self.agent = agent
        self.greedy = greedy

    def select(self, feats, groups, dataset_bytes, state) -> int:
        mask = np.zeros((self.agent.cfg.num_actions,), bool)
        mask[:len(feats)] = True
        return self.agent.select(state, mask, greedy=self.greedy)


def partitioning_creation(producer, dataset: str, history: HistoryStore,
                          selector=None, *, dataset_bytes: float = 0.0,
                          max_candidates: int = 12,
                          now: Optional[float] = None) -> PartitioningDecision:
    """Alg. 3.  ``producer`` is a traced Workload about to write ``dataset``."""
    t0 = time.perf_counter()
    now = now if now is not None else time.time()
    selector = selector or GreedySelector()

    # line 4: W ← match(p, W')  — consumers of past outputs of this producer IR
    psig = producer.graph.graph_signature()
    groups = history.enumerate_consumers(psig)

    # lines 5–11: candidate enumeration over every consumer IR
    cands: List[PartitionerCandidate] = []
    cand_groups: Dict[str, List[SkeletonNode]] = {}
    for g in groups:
        ir = history.ir_of(g.ir_signature)
        if ir is None:
            continue
        for c in enumerate_candidates(ir, dataset):
            cands.append(c)
            cand_groups.setdefault(c.signature(), []).append(g)
    cands = dedupe(cands)
    cands.extend(keyless_candidates())       # rr + random always in the space

    feats = [candidate_features(c, cand_groups.get(c.signature(), groups
                                                   if not c.is_keyed else []),
                                history, now)
             for c in cands]
    state = build_state(feats, dataset_bytes, max_candidates, now=now)

    # line 12: g_opt ← selector
    action = selector.select(feats, groups, dataset_bytes, state)
    action = min(action, len(feats) - 1)

    return PartitioningDecision(
        dataset=dataset, candidate=feats[action].candidate, features=feats,
        consumers=[g.ir_signature for g in groups], action_index=action,
        state=state, elapsed_s=time.perf_counter() - t0)


def apply_decision(store, decision: PartitioningDecision, *, mesh=None,
                   swap: bool = True):
    """Apply a :class:`PartitioningDecision` to a live store: repartition
    the dataset into the decided layout (device-to-device when both store
    and dataset are device-backed) and — with ``swap=True`` — atomically
    flip the dataset to the new generation so readers never observe a
    half-shuffled table (DESIGN §8).  Returns ``(new_dataset, bytes_moved)``.
    """
    ds = store.read(decision.dataset)
    return store.repartition(ds, decision.candidate, mesh=mesh, swap=swap)
