"""State feature vector for partitioner candidates (paper §3.1.3).

Per candidate: (distance, frequency, recency, complexity, selectivity,
key_distribution), plus the dataset-size estimate e_t appended to the state.
Keyless candidates (round-robin / random) get complexity = 0, selectivity =
1, key_distribution = avg number of elements in historical runs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .history import HistoryStore, SkeletonNode
from .partitioner import PartitionerCandidate

FEATURE_NAMES = ("distance", "frequency", "recency", "complexity",
                 "selectivity", "key_distribution")
NUM_FEATURES = len(FEATURE_NAMES)


@dataclass
class CandidateFeatures:
    candidate: PartitionerCandidate
    distance: float          # avg interval between most recent k runs
    frequency: float         # total historical executions of the origin IR
    recency: float           # timestamp of most recent run
    complexity: float        # shortest-path weight sum of the subgraph
    selectivity: float       # avg key bytes / avg object bytes
    key_distribution: float  # avg distinct hashed keys in historical runs

    def vector(self) -> np.ndarray:
        return np.array([self.distance, self.frequency, self.recency,
                         self.complexity, self.selectivity,
                         self.key_distribution], dtype=np.float32)


def candidate_features(cand: PartitionerCandidate,
                       groups: Sequence[SkeletonNode],
                       history: HistoryStore,
                       now: float,
                       recent_k: int = 5) -> CandidateFeatures:
    """Features of one candidate aggregated over the skeleton groups whose
    IRs contain it.  Aggregation follows §4.3: averages for distance/
    frequency/recency, max for selectivity, min for key distribution."""
    runs = [r for g in groups for r in g.runs]
    runs.sort(key=lambda r: r.timestamp)
    sig = cand.signature()

    if runs:
        # compaction-aware: an aggregate record stands for `weight` runs
        freq = float(sum(r.weight for r in runs))
        recency = runs[-1].timestamp
        recent = [r.timestamp for r in runs[-recent_k:]]
        distance = (float(np.mean(np.diff(recent))) if len(recent) > 1 else 0.0)
    else:
        freq, recency, distance = 0.0, 0.0, 0.0

    sel_samples, key_samples, count_samples = [], [], []
    for r in runs:
        st = r.candidate_stats.get(sig)
        if st:
            if "selectivity" in st:
                sel_samples.append(st["selectivity"])
            elif st.get("object_bytes"):
                sel_samples.append(st.get("key_bytes", 0.0) / st["object_bytes"])
            if "distinct_keys" in st:
                key_samples.append(st["distinct_keys"])
        if r.input_bytes:
            count_samples.append(st.get("num_objects", 0.0) if st else 0.0)

    if not cand.is_keyed:
        complexity = 0.0
        selectivity = 1.0
        key_dist = float(np.mean([c for c in count_samples if c > 0])) \
            if any(c > 0 for c in count_samples) else 0.0
    else:
        complexity = float(cand.complexity())
        selectivity = float(np.max(sel_samples)) if sel_samples else 0.0
        key_dist = float(np.min(key_samples)) if key_samples else 0.0

    return CandidateFeatures(cand, distance, freq, recency, complexity,
                             selectivity, key_dist)


def build_state(feats: Sequence[CandidateFeatures], dataset_bytes: float,
                max_candidates: int, now: float = 0.0) -> np.ndarray:
    """State s_t = (d, f, r, c, s, k per candidate ‖ e_t), zero-padded /
    truncated to ``max_candidates`` rows, normalized for network input."""
    rows = np.zeros((max_candidates, NUM_FEATURES), dtype=np.float32)
    for i, f in enumerate(feats[:max_candidates]):
        rows[i] = f.vector()
    # normalization: log-scale counts/sizes, recency as age
    out = rows.copy()
    out[:, 0] = np.log1p(rows[:, 0])                  # distance
    out[:, 1] = np.log1p(rows[:, 1])                  # frequency
    age = np.where(rows[:, 2] > 0, now - rows[:, 2], 1e6)
    out[:, 2] = 1.0 / (1.0 + np.log1p(np.maximum(age, 0)))  # recency → freshness
    out[:, 3] = rows[:, 3] / 10.0                     # complexity
    out[:, 4] = rows[:, 4]                            # selectivity ∈ [0, ~1]
    out[:, 5] = np.log1p(rows[:, 5]) / 20.0           # key distribution
    state = np.concatenate([out.reshape(-1),
                            np.array([np.log1p(dataset_bytes) / 30.0],
                                     dtype=np.float32)])
    return state


def state_dim(max_candidates: int) -> int:
    return max_candidates * NUM_FEATURES + 1
