"""Sharding advisor — Lachesis's selection loop applied to TPU shardings.

Beyond-paper extension (DESIGN §2): for an LM step function, the
"partitioner candidates" are sharding variants (config + spec knobs), the
"historical statistics" are the roofline terms derived from each variant's
compiled artifact, and the selector is Eq. 2's argmin over the dominant
term.  This is exactly the §Perf hillclimb, packaged as an advisor: give it
a cell and a candidate list, it lowers each, scores it, and returns the
winner with the full measurement trail (so the decision is auditable the
same way PartitioningDecision is).

The candidate space mirrors the knobs the paper's action space would hold:
    extra_cfg: accum_steps, remat_policy, mla_absorbed, ...
    variant:   cache_seq_shard, fsdp_params, flash_decode
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass
class ShardingCandidate:
    name: str
    extra_cfg: Dict[str, Any] = field(default_factory=dict)
    variant: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ShardingDecision:
    cell: Tuple[str, str, bool]
    winner: ShardingCandidate
    dominant_term_s: float
    trail: List[Dict[str, Any]]          # per-candidate roofline records


DEFAULT_CANDIDATES: Dict[str, List[ShardingCandidate]] = {
    "train": [
        ShardingCandidate("baseline"),
        ShardingCandidate("accum_half", {"accum_steps": 2}),
        ShardingCandidate("accum_1", {"accum_steps": 1}),
        ShardingCandidate("remat_dots", {"remat_policy": "dots"}),
    ],
    "decode": [
        ShardingCandidate("baseline"),
        ShardingCandidate("cache_seq_shard", {}, {"cache_seq_shard": True}),
        ShardingCandidate("flash_decode", {}, {"flash_decode": True}),
    ],
    "prefill": [ShardingCandidate("baseline")],
}


def dominant_term(record: Dict[str, Any]) -> float:
    return max(record["compute_s"], record["memory_s"],
               record["collective_s"])


def advise(arch: str, shape: str, *, multi_pod: bool = False,
           candidates: Optional[Sequence[ShardingCandidate]] = None,
           analyze=None) -> ShardingDecision:
    """Lower every candidate, score by the dominant roofline term, return
    the argmin.  ``analyze`` is injectable for tests (defaults to the real
    dry-run ``analyze_cell`` — requires the 512-device env flag)."""
    if analyze is None:
        from ..launch.dryrun import analyze_cell as analyze
    from ..configs import SHAPES
    kind = SHAPES[shape].kind
    cands = list(candidates) if candidates is not None \
        else DEFAULT_CANDIDATES[kind]

    trail: List[Dict[str, Any]] = []
    best: Optional[Tuple[float, ShardingCandidate]] = None
    for cand in cands:
        try:
            rec = analyze(arch, shape, multi_pod=multi_pod,
                          extra_cfg=cand.extra_cfg or None,
                          variant=cand.variant or None, verbose=False)
        except Exception as e:                    # candidate may not lower
            trail.append({"candidate": cand.name, "error": repr(e)})
            continue
        rec["candidate"] = cand.name
        trail.append(rec)
        score = dominant_term(rec)
        if best is None or score < best[0]:
            best = (score, cand)
    if best is None:
        raise RuntimeError("no sharding candidate lowered successfully")
    return ShardingDecision(cell=(arch, shape, multi_pod), winner=best[1],
                            dominant_term_s=best[0], trail=trail)
