"""Trace-driven training simulator (paper §4.3).

The paper accelerates DRL training by replaying (state, action, reward)
traces derived from actual runs of a few TPC-H queries: per (partitioner
candidate, query) statistics + measured latencies for each of the 431
partition schemes.  Training then samples random workloads (query mixes),
derives the state vector from the per-query statistics, and computes the
reward analytically from historical latencies — "like a database simulator".

We reproduce that design: a :class:`QueryStat` library (either measured from
our engine runs or synthesized), a workload sampler, and the reward =
throughput speedup vs. the historical average (paper's reward function).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..features import NUM_FEATURES, build_state, state_dim


@dataclass
class QueryStat:
    """Historical statistics of one query w.r.t. the candidate library."""
    query_id: str
    candidates: List[int]           # indices of candidates this query desires
    base_latency: float             # CPU-side latency (s), shuffle excluded
    shuffle_bytes: float            # bytes moved if its shuffle is NOT elided
    input_bytes: float
    # per-candidate stats (selectivity, distinct keys) for feature synthesis
    selectivity: Dict[int, float] = field(default_factory=dict)
    distinct_keys: Dict[int, float] = field(default_factory=dict)
    distance: float = 60.0          # mean inter-arrival (s)
    frequency: float = 10.0
    recency: float = 0.0


@dataclass
class SimConfig:
    num_candidates: int = 12        # K candidate slots (incl. rr + random)
    net_bandwidth: float = 1.25e9   # bytes/s (10 Gbps, paper's clusters)
    partition_overhead: float = 0.10  # ≤10% producer overhead (paper Tab. 3)
    queries_per_workload: Tuple[int, int] = (1, 4)
    seed: int = 0


class TraceSimulator:
    """Samples workloads and scores partitioning actions.

    Action space: index into the candidate library; the last two indices are
    always round-robin and random (keyless)."""

    def __init__(self, queries: Sequence[QueryStat], cfg: SimConfig,
                 complexities: Optional[Sequence[float]] = None):
        self.queries = list(queries)
        self.cfg = cfg
        self.K = cfg.num_candidates
        self.rr_action = self.K - 2
        self.rand_action = self.K - 1
        self.complexities = (list(complexities) if complexities is not None
                             else [1.0] * (self.K - 2)) + [0.0, 0.0]
        self._rng = np.random.default_rng(cfg.seed)
        # historical average throughput = every query run un-partitioned
        tot_b = sum(q.input_bytes * q.frequency for q in self.queries)
        tot_l = sum(self._latency(q, elided=False) * q.frequency
                    for q in self.queries)
        self.baseline_throughput = tot_b / tot_l

    # -- cost model -----------------------------------------------------------
    def _latency(self, q: QueryStat, elided: bool) -> float:
        shuffle = 0.0 if elided else q.shuffle_bytes / self.cfg.net_bandwidth
        return q.base_latency + shuffle

    # -- episode API -------------------------------------------------------------
    def sample_workload(self) -> List[Tuple[QueryStat, float]]:
        lo, hi = self.cfg.queries_per_workload
        n = int(self._rng.integers(lo, hi + 1))
        idx = self._rng.choice(len(self.queries), size=min(n, len(self.queries)),
                               replace=False)
        return [(self.queries[i], float(self._rng.uniform(0.3, 1.0)))
                for i in idx]

    def state_of(self, workload) -> Tuple[np.ndarray, np.ndarray]:
        """Build (state, action_mask).  Feature aggregation per §4.3: averages
        for distance/frequency/recency, max selectivity, min distinct keys."""
        rows = np.zeros((self.K, NUM_FEATURES), np.float32)
        mask = np.zeros((self.K,), bool)
        mask[self.rr_action] = mask[self.rand_action] = True
        total_objs = sum(q.input_bytes for q, _f in workload) / 64.0
        for k in range(self.K - 2):
            qs = [(q, f) for q, f in workload if k in q.candidates]
            if not qs:
                continue
            mask[k] = True
            rows[k, 0] = np.mean([q.distance for q, _ in qs])
            rows[k, 1] = np.sum([q.frequency * f for q, f in qs])
            rows[k, 2] = np.max([q.recency for q, _ in qs])
            rows[k, 3] = self.complexities[k]
            rows[k, 4] = np.max([q.selectivity.get(k, 0.0) for q, _ in qs])
            rows[k, 5] = np.min([q.distinct_keys.get(k, 1.0) for q, _ in qs])
        # keyless rows: complexity 0, selectivity 1, key_dist = avg #elements
        for k in (self.rr_action, self.rand_action):
            rows[k, 4] = 1.0
            rows[k, 5] = total_objs
        dataset_bytes = sum(q.input_bytes for q, _f in workload)
        state = _rows_to_state(rows, dataset_bytes)
        return state, mask

    def reward_of(self, workload, action: int) -> float:
        """Paper's reward: throughput with the chosen partitioning divided by
        the historical-average (baseline) throughput."""
        tot_b, tot_l = 0.0, 0.0
        keyed = action < self.K - 2
        for q, f in workload:
            elided = keyed and (action in q.candidates)
            lat = self._latency(q, elided)
            if keyed:
                lat *= (1.0 + self.cfg.partition_overhead /
                        max(1.0, q.frequency))
            # skew penalty: few distinct keys → imbalance stretches latency
            if elided:
                dk = q.distinct_keys.get(action, 64.0)
                lat *= 1.0 + max(0.0, (8.0 - dk)) / 8.0
            tot_b += q.input_bytes * q.frequency * f
            tot_l += lat * q.frequency * f
        return (tot_b / tot_l) / self.baseline_throughput

    def best_action(self, workload) -> int:
        _, mask = self.state_of(workload)
        rewards = [self.reward_of(workload, a) if mask[a] else -np.inf
                   for a in range(self.K)]
        return int(np.argmax(rewards))

    @property
    def state_dim(self) -> int:
        return state_dim(self.K)


def _rows_to_state(rows: np.ndarray, dataset_bytes: float) -> np.ndarray:
    out = rows.copy()
    out[:, 0] = np.log1p(rows[:, 0])
    out[:, 1] = np.log1p(rows[:, 1])
    out[:, 2] = 1.0 / (1.0 + np.log1p(np.maximum(rows[:, 2], 0)))
    out[:, 3] = rows[:, 3] / 10.0
    out[:, 5] = np.log1p(rows[:, 5]) / 20.0
    return np.concatenate([out.reshape(-1),
                           [np.float32(np.log1p(dataset_bytes) / 30.0)]]
                          ).astype(np.float32)


# ---------------------------------------------------------------------------
# Synthetic TPC-H-like trace library (stand-in for the paper's 1293 measured
# runs; the shape — queries × candidates × latencies — is identical).
# ---------------------------------------------------------------------------

def tpch_like_library(num_queries: int = 10, num_keyed: int = 10,
                      seed: int = 7) -> Tuple[List[QueryStat], SimConfig]:
    rng = np.random.default_rng(seed)
    queries = []
    for i in range(num_queries):
        cands = sorted(rng.choice(num_keyed,
                                  size=int(rng.integers(1, 4)),
                                  replace=False).tolist())
        inp = float(rng.uniform(1, 12)) * 1e9
        queries.append(QueryStat(
            query_id=f"Q{i+1:02d}",
            candidates=cands,
            base_latency=float(rng.uniform(4, 40)),
            shuffle_bytes=inp * float(rng.uniform(0.1, 0.9)),
            input_bytes=inp,
            selectivity={k: float(rng.uniform(0.02, 0.6)) for k in cands},
            distinct_keys={k: float(rng.uniform(2, 1e6)) for k in cands},
            distance=float(rng.uniform(10, 600)),
            frequency=float(rng.integers(1, 40)),
            recency=float(rng.uniform(0, 1e4)),
        ))
    return queries, SimConfig(num_candidates=num_keyed + 2, seed=seed)
