"""A3C-style advantage actor-critic agent (paper §3.1.3).

Update rule (paper):
    θ ← θ + α ∇θ log πθ(s,a) A(s,a) + β ∇θ H(π(·|s))
with A(s,a) = R - V(s) from the critic, entropy bonus β for exploration.

The paper runs the agent as a TensorFlow server process; here it is a pure
JAX module — the "server" boundary is preserved by the advisor calling only
``select`` / ``observe`` / ``train_batch``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...optimizer.adamw import AdamW
from . import networks


class Transition(NamedTuple):
    state: np.ndarray
    action: int
    reward: float
    mask: np.ndarray


@dataclass
class A3CConfig:
    state_dim: int
    num_actions: int
    lr: float = 3e-4
    gamma: float = 0.9
    entropy_beta: float = 0.05
    value_coef: float = 0.5
    seed: int = 0


class A3CAgent:
    def __init__(self, cfg: A3CConfig):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        self.params = networks.init_actor_critic(key, cfg.state_dim,
                                                 cfg.num_actions)
        self.opt = AdamW(lr=cfg.lr, weight_decay=0.0, grad_clip_norm=5.0)
        self.opt_state = self.opt.init(self.params)
        self._rng = np.random.default_rng(cfg.seed)
        self._update = jax.jit(self._update_impl)

    # -- acting ------------------------------------------------------------------
    def select(self, state: np.ndarray, mask: Optional[np.ndarray] = None,
               greedy: bool = False) -> int:
        mask_arr = (jnp.asarray(mask, bool) if mask is not None
                    else jnp.ones((self.cfg.num_actions,), bool))
        probs = np.asarray(networks.policy(self.params, jnp.asarray(state),
                                           mask_arr))
        probs = probs / probs.sum()
        if greedy:
            return int(np.argmax(probs))
        return int(self._rng.choice(len(probs), p=probs))

    # -- learning -----------------------------------------------------------------
    def _update_impl(self, params, opt_state, states, actions, returns, masks):
        def loss_fn(p):
            logits = networks.policy_logits(p, states, masks)
            logp = jax.nn.log_softmax(logits, axis=-1)
            probs = jnp.exp(logp)
            v = networks.value(p, states)
            adv = returns - v
            pg = -jnp.mean(logp[jnp.arange(actions.shape[0]), actions]
                           * jax.lax.stop_gradient(adv))
            ent = -jnp.mean(jnp.sum(jnp.where(masks, probs * logp, 0.0),
                                    axis=-1))
            vloss = jnp.mean(jnp.square(adv))
            total = pg + self.cfg.value_coef * vloss - self.cfg.entropy_beta * ent
            return total, (pg, vloss, ent)

        (total, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt_state = self.opt.update(grads, opt_state, params)
        return new_params, new_opt_state, total, aux

    def train_batch(self, batch: List[Transition]) -> Tuple[float, dict]:
        """One gradient step on a batch of transitions.  Rewards here are the
        immediate rewards of one-shot partitioning decisions; with γ we fold
        in the discounted future return within an episode trace."""
        states = jnp.asarray(np.stack([t.state for t in batch]))
        actions = jnp.asarray(np.array([t.action for t in batch], np.int32))
        masks = jnp.asarray(np.stack([t.mask for t in batch]))
        # discounted returns per-episode suffix (batch arrives episode-ordered)
        returns = np.zeros(len(batch), np.float32)
        run = 0.0
        for i in reversed(range(len(batch))):
            run = batch[i].reward + self.cfg.gamma * run
            returns[i] = run
        self.params, self.opt_state, total, (pg, vl, ent) = self._update(
            self.params, self.opt_state, states, actions,
            jnp.asarray(returns), masks)
        return float(total), {"policy_loss": float(pg),
                              "value_loss": float(vl),
                              "entropy": float(ent)}
