"""Actor-critic networks (paper §3.1.3, §5.4), pure JAX.

Both nets are 3-layer MLPs: hidden 128 → 64, leaky-relu activations; the
actor head is a masked softmax over the candidate slots, the critic head is
linear (scalar value) — exactly the architecture reported in §5.4.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

HIDDEN = (128, 64)


def init_mlp(key: jax.Array, sizes: List[int]) -> List[Dict[str, jax.Array]]:
    params = []
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
        params.append({"w": w, "b": jnp.zeros((dout,), jnp.float32)})
    return params


def mlp_forward(params, x, final_linear: bool = True):
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1 or not final_linear:
            h = jax.nn.leaky_relu(h, negative_slope=0.01)
    return h


def init_actor_critic(key: jax.Array, state_dim: int, num_actions: int):
    ka, kc = jax.random.split(key)
    actor = init_mlp(ka, [state_dim, *HIDDEN, num_actions])
    critic = init_mlp(kc, [state_dim, *HIDDEN, 1])
    return {"actor": actor, "critic": critic}


def policy_logits(params, state, action_mask=None):
    logits = mlp_forward(params["actor"], state)
    if action_mask is not None:
        logits = jnp.where(action_mask, logits, -1e9)
    return logits


def policy(params, state, action_mask=None):
    return jax.nn.softmax(policy_logits(params, state, action_mask), axis=-1)


def value(params, state):
    return mlp_forward(params["critic"], state)[..., 0]
