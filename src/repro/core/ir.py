"""Graph IR for UDF-centric workloads (paper §2.2.1).

The paper's IR assumption: a workload maps to a DAG where each node is an
*atomic computation* that remains individually executable after compilation
(PlinyCompute lambda-calculus property).  We reproduce that property: every
node carries an executable ``fn`` over jax/numpy values, so any subgraph —
in particular a two-terminal partitioner candidate — can be compiled back
into a jittable key-projection function via :meth:`IRGraph.compile_fn`.

Node categories (paper §2.2.1):
  (1) lambda abstractions     — ``attr:<name>``, ``literal:<v>``, ``func:<u>``,
                                ``parse:<fmt>``, ``opaque:<tag>``
  (2) higher-order composites — ``binop:<op>``, ``cond``
  (3) set-based operators     — ``scan``, ``write``, ``partition``, ``apply``,
                                ``join``, ``aggregate``, ``filter``, ``flatten``
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Atomic-op registry: label prefix -> fn factory.  Mirrors the paper's
# "each atomic computation is executable separately".
# ---------------------------------------------------------------------------

_UNARY_FUNCS: Dict[str, Callable] = {
    "exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt, "sin": jnp.sin,
    "cos": jnp.cos, "tan": jnp.tan, "abs": jnp.abs, "neg": lambda x: -x,
    "lower": lambda x: x,  # string ops are identity on coded columns
    "hash": lambda x: _mix_hash(x),
}

_BINOPS: Dict[str, Callable] = {
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b, ">": lambda a, b: a > b,
    "<=": lambda a, b: a <= b, ">=": lambda a, b: a >= b,
    "+": lambda a, b: a + b, "-": lambda a, b: a - b,
    "*": lambda a, b: a * b, "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    "&&": lambda a, b: jnp.logical_and(a, b),
    "||": lambda a, b: jnp.logical_or(a, b),
    "&": lambda a, b: a & b, "|": lambda a, b: a | b,
}


def _mix_hash(x):
    """Deterministic 32-bit integer mix (Wang hash) used as the hash lambda."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.integer):
        x = x.view(jnp.int32) if x.dtype == jnp.float32 else x.astype(jnp.int32)
    x = x.astype(jnp.uint32)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def resolve_fn(label: str, params: Dict[str, Any]) -> Optional[Callable]:
    """Return the executable callable for an atomic-op label, if any."""
    kind, _, arg = label.partition(":")
    if kind == "scan" or kind == "partition" or kind == "write":
        return lambda x: x
    if kind == "parse":
        # Adaptation: our store is columnar/pre-parsed; parse is structural.
        return lambda x: x
    if kind == "attr":
        name = arg
        return lambda x, _n=name: x[_n] if isinstance(x, dict) else x
    if kind == "index":
        i = int(arg)
        return lambda x, _i=i: x[..., _i]
    if kind == "literal":
        val = params.get("value")
        return lambda *_xs, _v=val: jnp.asarray(_v)
    if kind == "func":
        return _UNARY_FUNCS.get(arg)
    if kind == "binop":
        return _BINOPS.get(arg)
    if kind == "cond":
        return lambda c, t, f: jnp.where(c, t, f)
    if kind == "opaque":
        return params.get("fn")
    # set-based ops (apply/join/aggregate/filter/flatten) are executed by the
    # engine (repro.core.engine), not by subgraph compilation.
    return params.get("fn")


# ---------------------------------------------------------------------------
# Nodes and graphs
# ---------------------------------------------------------------------------

SET_OPS = ("scan", "write", "partition", "apply", "join", "aggregate",
           "filter", "flatten")


@dataclass
class Node:
    id: int
    label: str                      # canonical op label, used in signatures
    params: Dict[str, Any] = field(default_factory=dict)

    @property
    def kind(self) -> str:
        return self.label.partition(":")[0]

    @property
    def is_partition(self) -> bool:
        return self.kind == "partition"

    @property
    def is_scan(self) -> bool:
        return self.kind == "scan"

    @property
    def is_write(self) -> bool:
        return self.kind == "write"

    def signature_token(self) -> str:
        """Label token contributing to path signatures.  Strategy of a
        partition node is part of its identity (paper §2.2.3)."""
        if self.is_partition:
            return f"partition[{self.params.get('strategy', 'hash')}]"
        if self.is_scan:
            # dataset identity is NOT in the token: matching is structural,
            # the same key-projection applies to any dataset read the same way
            return "scan"
        return self.label

    def fn(self) -> Optional[Callable]:
        return resolve_fn(self.label, self.params)


class IRGraph:
    """A DAG IR: ``a = (V, E, S, O)`` per paper §2.2.1."""

    def __init__(self) -> None:
        self.nodes: Dict[int, Node] = {}
        self._children: Dict[int, List[int]] = {}
        self._parents: Dict[int, List[int]] = {}   # ordered (binop arg order)
        self._next_id = 0
        self._sig_cache: Optional[str] = None      # memoized graph_signature

    # -- construction -------------------------------------------------------
    def add_node(self, label: str, params: Optional[Dict[str, Any]] = None) -> int:
        nid = self._next_id
        self._next_id += 1
        self.nodes[nid] = Node(nid, label, dict(params or {}))
        self._children[nid] = []
        self._parents[nid] = []
        self._sig_cache = None
        return nid

    def add_edge(self, src: int, dst: int) -> None:
        if src not in self.nodes or dst not in self.nodes:
            raise KeyError(f"edge ({src},{dst}) references unknown node")
        self._children[src].append(dst)
        self._parents[dst].append(src)
        self._sig_cache = None

    # -- accessors -----------------------------------------------------------
    def children(self, nid: int) -> List[int]:
        return self._children[nid]

    def parents(self, nid: int) -> List[int]:
        return self._parents[nid]

    @property
    def edges(self) -> List[Tuple[int, int]]:
        return [(s, d) for s, cs in self._children.items() for d in cs]

    @property
    def scans(self) -> List[int]:
        return [n.id for n in self.nodes.values() if n.is_scan]

    @property
    def writes(self) -> List[int]:
        return [n.id for n in self.nodes.values() if n.is_write]

    @property
    def partition_nodes(self) -> List[int]:
        return [n.id for n in self.nodes.values() if n.is_partition]

    def find_scanner(self, dataset: str) -> Optional[int]:
        for nid in self.scans:
            if self.nodes[nid].params.get("dataset") == dataset:
                return nid
        return None

    # -- structure -----------------------------------------------------------
    def toposort(self, within: Optional[Set[int]] = None) -> List[int]:
        ids = set(self.nodes) if within is None else set(within)
        indeg = {i: sum(1 for p in self._parents[i] if p in ids) for i in ids}
        frontier = sorted(i for i in ids if indeg[i] == 0)
        out: List[int] = []
        while frontier:
            n = frontier.pop(0)
            out.append(n)
            for c in self._children[n]:
                if c in ids:
                    indeg[c] -= 1
                    if indeg[c] == 0:
                        frontier.append(c)
            frontier.sort()
        if len(out) != len(ids):
            raise ValueError("IR graph contains a cycle")
        return out

    def all_paths(self, src: int, dst: int, limit: int = 10_000) -> List[List[int]]:
        """All simple src→dst paths (DFS).  Analytics IR DAGs are small."""
        paths: List[List[int]] = []
        stack: List[Tuple[int, List[int]]] = [(src, [src])]
        while stack:
            node, path = stack.pop()
            if node == dst:
                paths.append(path)
                if len(paths) >= limit:
                    break
                continue
            for c in self._children[node]:
                if c not in path:
                    stack.append((c, path + [c]))
        return paths

    def subgraph(self, node_ids: Sequence[int]) -> "IRGraph":
        keep = set(node_ids)
        g = IRGraph()
        remap: Dict[int, int] = {}
        for nid in self.toposort(within=keep):
            n = self.nodes[nid]
            remap[nid] = g.add_node(n.label, n.params)
        for s, d in self.edges:
            if s in keep and d in keep:
                g.add_edge(remap[s], remap[d])
        return g

    # -- signatures (paper §3.1.1 / §3.2) -------------------------------------
    def path_signature(self, path: Sequence[int]) -> str:
        return "/".join(self.nodes[n].signature_token() for n in path)

    def path_signatures(self, src: int, dst: int) -> List[str]:
        return sorted(self.path_signature(p) for p in self.all_paths(src, dst))

    def graph_signature(self) -> str:
        """Hash signature per §3.1.1: enumerate, sort and concatenate all
        distinct scan→leaf path signatures.

        The paper hashes scan→write paths; we additionally include paths to
        non-write leaves (e.g. a partition branch that feeds no write) so
        two workloads differing only in such a branch never collide — a
        strict refinement (identical to the paper whenever writes are the
        only leaves).

        Memoized until the graph structure changes (``add_node`` /
        ``add_edge`` invalidate): the signature keys the Session's
        PhysicalPlan cache, so repeated runs of a frozen workload must not
        pay the path enumeration again."""
        if self._sig_cache is not None:
            return self._sig_cache
        sigs: List[str] = []
        leaves = self.leaves()
        for s in self.scans:
            for o in leaves:
                if o == s:
                    continue
                sigs.extend(self.path_signature(p) for p in self.all_paths(s, o))
        digest = hashlib.sha256("|".join(sorted(set(sigs))).encode()).hexdigest()
        self._sig_cache = digest
        return digest

    # -- two-terminal property -------------------------------------------------
    def roots(self) -> List[int]:
        return [i for i in self.nodes if not self._parents[i]]

    def leaves(self) -> List[int]:
        return [i for i in self.nodes if not self._children[i]]

    def is_two_terminal(self) -> bool:
        return len(self.roots()) == 1 and len(self.leaves()) == 1

    # -- executability: the PlinyCompute property ------------------------------
    def compile_fn(self) -> Callable:
        """Compose node fns of a two-terminal subgraph into one callable
        ``f(dataset_value) -> key``.  Requires every node fn to resolve."""
        if not self.is_two_terminal():
            raise ValueError("compile_fn requires a two-terminal subgraph")
        (root,), (leaf,) = self.roots(), self.leaves()
        order = self.toposort()
        fns = {}
        for nid in order:
            fn = self.nodes[nid].fn()
            if fn is None:
                raise ValueError(
                    f"node {nid} ({self.nodes[nid].label}) is not executable")
            fns[nid] = fn

        parents = {i: list(self._parents[i]) for i in order}

        def run(value):
            vals: Dict[int, Any] = {}
            for nid in order:
                if nid == root:
                    vals[nid] = fns[nid](value)
                else:
                    args = [vals[p] for p in parents[nid]]
                    vals[nid] = fns[nid](*args)
            return vals[leaf]

        return run

    # -- misc -------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def pretty(self) -> str:
        lines = []
        for nid in self.toposort():
            n = self.nodes[nid]
            kids = ",".join(map(str, self._children[nid])) or "-"
            lines.append(f"  [{nid}] {n.label} -> {kids}")
        return "\n".join(lines)
