"""Backend registry — capability-queried execution backends (DESIGN §9).

Replaces the stringly-typed ``backend="host"/"device"`` flags that every
entry point (Engine, PartitionStore, Session, benchmarks) used to validate
independently — and that, when misspelled, either surfaced as a bare
``KeyError`` or silently fell through to the host path.  All lookups now
go through one :class:`BackendRegistry`; an unregistered name raises
:class:`UnknownBackendError` listing what *is* registered.

A :class:`Backend` is a frozen capability descriptor, not an executor:
the planner queries it to bind each partition node to a concrete op
(``device_rebucket`` vs ``host_argsort``), the store queries it to decide
whether columns live device-resident.  Third-party backends plug in via
``REGISTRY.register`` (e.g. a future multi-host backend) without touching
planner or executor dispatch tables — unknown capabilities simply bind to
the host ops.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

__all__ = ["Backend", "BackendRegistry", "UnknownBackendError", "REGISTRY",
           "resolve_backend", "backend_names"]


class UnknownBackendError(KeyError, ValueError):
    """Raised for a ``backend=`` name that is not in the registry.

    Subclasses both ``KeyError`` (the historical dict-miss failure mode)
    and ``ValueError`` (the historical explicit-validation failure mode)
    so every pre-registry ``except`` clause keeps catching it.
    """

    def __init__(self, name: object, registered: Tuple[str, ...]):
        self.backend = name
        self.registered = tuple(registered)
        self.message = (
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(self.registered) or '(none)'}")
        super().__init__(self.message)

    def __str__(self) -> str:           # KeyError.__str__ would repr()-quote
        return self.message


@dataclass(frozen=True)
class Backend:
    """Capability descriptor for one execution backend."""

    name: str
    #: columns of stored datasets live as jax arrays on the accelerator
    device_resident: bool = False
    #: hash shuffles route through the cached ShufflePlan kernels (DESIGN §5)
    kernel_shuffle: bool = False
    #: scans relay flat device columns downstream (d2d chain, DESIGN §5)
    device_relay: bool = False
    #: reading a spilled dataset from a durable store promotes it
    #: host→device (DESIGN §10 eviction loop); host backends read straight
    #: through the lazy memmap views instead
    storage_prefetch: bool = False
    description: str = ""

    def partition_op(self, strategy: str) -> str:
        """The concrete op a partition node binds to under this backend.

        The ShufflePlan mode (fused kernels on TPU, hostperm off-TPU) is
        resolved lazily at plan time so one registry serves both platforms.
        """
        if self.kernel_shuffle and strategy == "hash":
            from ..data.device_repartition import default_mode
            return f"device_rebucket[{default_mode()}]"
        if strategy == "range":
            return "host_range"
        return "host_argsort"


class BackendRegistry:
    """Name → :class:`Backend`, with clear errors for unknown names."""

    def __init__(self) -> None:
        self._backends: Dict[str, Backend] = {}

    def register(self, backend: Backend, *, overwrite: bool = False) -> Backend:
        if backend.name in self._backends and not overwrite:
            raise ValueError(f"backend {backend.name!r} already registered "
                             "(pass overwrite=True to replace)")
        self._backends[backend.name] = backend
        return backend

    def get(self, name) -> Backend:
        if isinstance(name, Backend):
            return name
        backend = self._backends.get(name)
        if backend is None:
            raise UnknownBackendError(name, self.names())
        return backend

    def names(self) -> Tuple[str, ...]:
        return tuple(self._backends)

    def __contains__(self, name: object) -> bool:
        return name in self._backends

    def __iter__(self) -> Iterator[Backend]:
        return iter(self._backends.values())

    def with_capability(self, **caps: bool) -> Tuple[Backend, ...]:
        """Backends whose descriptor matches every given capability flag,
        e.g. ``registry.with_capability(kernel_shuffle=True)``."""
        out = []
        for b in self._backends.values():
            if all(getattr(b, k) == v for k, v in caps.items()):
                out.append(b)
        return tuple(out)


#: The process-wide default registry, pre-seeded with the two built-ins.
REGISTRY = BackendRegistry()
REGISTRY.register(Backend(
    "host",
    description="numpy columnar execution; shuffles via stable argsort"))
REGISTRY.register(Backend(
    "device", device_resident=True, kernel_shuffle=True, device_relay=True,
    storage_prefetch=True,
    description="device-resident columns; hash shuffles via cached "
                "single-pass ShufflePlans (Pallas kernels on TPU)"))


def resolve_backend(name, registry: BackendRegistry = None) -> Backend:
    """Resolve ``name`` (str or Backend) or raise :class:`UnknownBackendError`."""
    return (registry or REGISTRY).get(name)


def backend_names() -> Tuple[str, ...]:
    return REGISTRY.names()
