"""Planner — Workload DSL → LogicalPlan → PhysicalPlan (DESIGN §9).

The paper's thesis is that UDF workloads become optimizable once they are
*analyzable, reusable sub-computations*; this module is where that pays
off at execution time.  Planning happens in two stages:

``Planner.logical``
    Normalizes a traced :class:`~repro.core.dsl.Workload` into a
    :class:`LogicalPlan`: topological node order, the partitioner
    candidates extracted per partition node (Alg. 1+2), the scanned
    datasets, and the memoized IR signature.

``Planner.compile``
    Binds a LogicalPlan against one :class:`~repro.core.backends.Backend`
    and the *current* store layout into a frozen :class:`PhysicalPlan`:
    every partition node gets an elide-vs-shuffle decision (Alg. 4 run
    **statically at plan time** against the pinned layout generation), a
    concrete backend op (``device_rebucket[fused|hostperm]`` /
    ``host_argsort`` / ``host_range``) and — where the input cardinality
    is statically known — the ShufflePlan shape bucket the device path
    will dispatch through.

``Planner.physical`` caches PhysicalPlans in an LRU keyed by IR signature
× backend × worker count × per-dataset ``(generation, partitioner)``
layout pins, so re-running an unchanged workload on an unchanged store is
a pure cache hit (no candidate extraction, no Alg. 4, no jax re-trace),
while a layout-generation flip invalidates exactly the plans that scanned
the flipped dataset.
"""

from __future__ import annotations

import hashlib
import itertools
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from .backends import Backend, BackendRegistry, REGISTRY
from .ir import IRGraph, SET_OPS
from .matching import partitioning_match
from .partitioner import PartitionerCandidate, merge, search
from ..data.partition_store import RetiredGenerationError
from ..obs import metrics as _obs_metrics
from ..obs.tracer import span as _span

__all__ = ["LogicalPlan", "PhysicalPlan", "PlanKey", "PlanStep", "Planner"]


# ---------------------------------------------------------------------------
# Plan artifacts
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanKey:
    """Identity of a PhysicalPlan: IR skeleton × node params × backend ×
    store layout.

    ``layout`` pins ``(dataset, generation, partitioner signature)`` for
    every dataset the workload scans — any repartition/rewrite bumps the
    generation and therefore misses the cache for exactly the plans that
    read that dataset.  ``param_signature`` covers what the structural IR
    signature deliberately drops (opaque fns, projections, reducers,
    scan/write dataset names): two structurally identical workloads with
    different UDFs or write targets must never share a plan, because a
    cached plan replays its own graph's params."""
    ir_signature: str
    param_signature: str
    backend: str
    num_workers: int
    matching: bool
    layout: Tuple[Tuple[str, int, str], ...]
    #: cluster placement epoch (DESIGN §14): the planner consults the
    #: PartitionDirectory when keying, so a rebalance — which changes
    #: where partitions live without changing their contents — still
    #: invalidates exactly the plans compiled against the old placement.
    #: -1 on non-cluster stores (constant, so their keys are unchanged).
    placement_epoch: int = -1


_PRIMITIVES = (bool, int, float, str, bytes, type(None))


def param_signature(g: IRGraph) -> str:
    """Fingerprint of every node's params (O(nodes), cheap per run).

    Primitives fingerprint by value; callables and other objects by
    ``id`` — the cache is per-process, so identity is sound: a rebuilt
    lambda gets a fresh id and correctly misses, while reusing the same
    function object (or a param-free workload, like every canned one)
    keeps hitting across freshly traced workloads."""
    parts: List[str] = []
    for nid in sorted(g.nodes):
        for k in sorted(g.nodes[nid].params):
            v = g.nodes[nid].params[k]
            if v is None:
                continue
            if isinstance(v, _PRIMITIVES):
                parts.append(f"{nid}.{k}={v!r}")
            elif isinstance(v, tuple) and all(
                    isinstance(x, _PRIMITIVES) for x in v):
                parts.append(f"{nid}.{k}={v!r}")
            else:
                parts.append(f"{nid}.{k}=obj#{id(v)}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]


@dataclass
class LogicalPlan:
    """Normalized IR + candidate annotations (backend-independent)."""
    workload: Any
    graph: IRGraph
    order: Tuple[int, ...]                       # toposorted node ids
    candidates: Dict[int, PartitionerCandidate]  # per partition node (Alg. 1+2)
    scan_datasets: Tuple[str, ...]               # sorted unique scanned names
    ir_signature: str

    @property
    def workload_id(self) -> str:
        return getattr(self.workload, "app_id", "<workload>")


@dataclass
class PlanStep:
    """One bound node of a PhysicalPlan.  ``kind`` selects the executor
    path; the optional fields carry the plan-time bindings for that kind."""
    nid: int
    kind: str
    label: str
    # scan
    dataset: str = ""
    generation: int = -1
    rows: int = -1
    device_relay: bool = False
    # partition
    key_node: int = -1
    strategy: str = ""
    candidate: Optional[PartitionerCandidate] = None
    elide: bool = False
    device_op: bool = False
    op: str = ""                     # bound backend op label (explain/debug)
    bucket: Optional[int] = None     # ShufflePlan shape bucket, if static
    # join
    projection: Optional[Callable] = None


@dataclass
class PhysicalPlan:
    """A frozen, executable artifact: the workload's nodes bound to
    concrete backend ops against one pinned store layout.

    Execute with :class:`~repro.core.executor.Executor`; mutate nothing.
    Executing against a store whose generations moved past the pinned ones
    raises ``StalePlanError`` (``Session.run`` re-plans automatically)."""
    key: PlanKey
    workload: Any
    workload_id: str
    graph: IRGraph
    steps: Tuple[PlanStep, ...]
    backend: Backend
    elided: Tuple[int, ...]          # partition nids elided at plan time
    shuffled: Tuple[int, ...]        # partition nids bound to a real shuffle
    match_overhead_s: float = 0.0    # plan-time Alg. 4 wall
    pinned: bool = True              # executor enforces generation pins

    # ------------------------------------------------------------- explain --
    def explain(self) -> str:
        """Deterministic plan dump: per partition node the decision, bound
        backend op, and ShufflePlan bucket; plus the layout pins that key
        the cache.  Contains no timestamps, addresses or wall-clock."""
        lines = [f"PhysicalPlan {self.workload_id} "
                 f"backend={self.backend.name} workers={self.key.num_workers} "
                 f"matching={'on' if self.key.matching else 'off'}",
                 f"  ir: {self.key.ir_signature[:12]}"]
        layout = " ".join(
            f"{name}@gen{gen}[{sig or 'unpartitioned'}]"
            for name, gen, sig in self.key.layout) or "(no scans)"
        lines.append(f"  layout: {layout}")
        if self.key.placement_epoch >= 0:
            lines.append("  placement: directory epoch "
                         f"{self.key.placement_epoch} (cluster)")
        lines.append("  steps:")
        for s in self.steps:
            if s.kind == "scan":
                lines.append(f"    [{s.nid:3d}] scan {s.dataset} "
                             f"rows={s.rows} gen={s.generation}")
            elif s.kind == "partition":
                head = (f"    [{s.nid:3d}] partition[{s.strategy}] "
                        f"key<-n{s.key_node}")
                if s.dataset:
                    head += f" src={s.dataset}"
                if s.elide:
                    cand = s.candidate.signature() if s.candidate else "?"
                    lines.append(f"{head} ELIDED (Alg.4 static: layout "
                                 f"matches {cand})")
                else:
                    bucket = f"B{s.bucket}" if s.bucket else "dynamic"
                    lines.append(f"{head} op={s.op} bucket={bucket} shuffle")
            elif s.kind == "write":
                lines.append(f"    [{s.nid:3d}] write {s.dataset}")
            else:
                lines.append(f"    [{s.nid:3d}] {s.label}")
        lines.append(f"  shuffles: elided={len(self.elided)} "
                     f"performed={len(self.shuffled)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------

class Planner:
    """Builds and caches PhysicalPlans for one store.

    ``cache_stats()`` exposes hit/miss/eviction counters; the companion
    jax-level trace counter lives in ``data.device_repartition.
    plan_cache_stats()`` (Session merges both)."""

    _ids = itertools.count(1)        # per-process planner instance label

    def __init__(self, store, *, registry: BackendRegistry = None,
                 matching: bool = True, cache_capacity: int = 128,
                 metrics: "_obs_metrics.MetricsRegistry" = None):
        if cache_capacity < 1:
            raise ValueError("plan cache capacity must be >= 1")
        self.store = store
        self.registry = registry or REGISTRY
        self.matching = matching
        self.cache_capacity = cache_capacity
        self._cache: "OrderedDict[PlanKey, PhysicalPlan]" = OrderedDict()
        # cache counters live in the MetricsRegistry (labeled per planner
        # instance so shared-registry sessions don't collide);
        # cache_stats() is a view over them — same keys/values as the old
        # private dict, now also exported via metrics()/prometheus_text()
        self.metrics = metrics or _obs_metrics.REGISTRY
        labels = {"planner": f"p{next(Planner._ids)}"}
        self._stats = {
            name: self.metrics.counter(
                f"planner_plan_cache_{name}_total",
                f"PhysicalPlan cache {name}", labels)
            for name in ("hits", "misses", "evictions", "invalidations")}
        self.metrics.register_callback(self, Planner._metric_samples)
        self._metric_labels = labels
        # guards _cache and _stats: the serving tier plans from many
        # threads against one shared planner (DESIGN §11).  Held only
        # around the OrderedDict/counter touches — compiles run outside
        # it, so concurrent different-key compiles proceed in parallel.
        self._lock = threading.RLock()

    def _metric_samples(self):
        yield ("planner_plan_cache_size", self._metric_labels,
               len(self._cache))

    # ------------------------------------------------------- logical stage --
    def logical(self, workload) -> LogicalPlan:
        """Workload DSL → normalized IR + candidate annotations."""
        g: IRGraph = workload.graph
        candidates: Dict[int, PartitionerCandidate] = {}
        for s in g.scans:
            for c in merge(g, search(g, s)):
                candidates[c.origin[1]] = c
        scans = tuple(sorted({g.nodes[s].params["dataset"]
                              for s in g.scans}))
        return LogicalPlan(workload=workload, graph=g,
                           order=tuple(g.toposort()), candidates=candidates,
                           scan_datasets=scans,
                           ir_signature=g.graph_signature())

    # ----------------------------------------------------------- cache key --
    def plan_key(self, workload, backend) -> PlanKey:
        """Cache identity for (workload, backend) against the live store."""
        backend = self.registry.get(backend)
        g: IRGraph = workload.graph
        layout = []
        for name in sorted({g.nodes[s].params["dataset"] for s in g.scans}):
            ds = self.store.datasets.get(name)
            if ds is None:
                layout.append((name, -1, ""))
            else:
                sig = ds.partitioner.signature() if ds.partitioner else ""
                layout.append((name, ds.generation, sig))
        return PlanKey(ir_signature=g.graph_signature(),
                       param_signature=param_signature(g),
                       backend=backend.name,
                       num_workers=self.store.m, matching=self.matching,
                       layout=tuple(layout),
                       placement_epoch=getattr(self.store,
                                               "placement_epoch", -1))

    # ---------------------------------------------------------- physical ----
    def physical(self, workload, backend) -> Tuple[PhysicalPlan, bool]:
        """Cached compile: returns ``(plan, cache_hit)``.

        The compile pins exactly the key's layout generations (not a
        second live read of the store), so a concurrent swap landing
        between key computation and compile can never cache a plan whose
        steps disagree with its key; if the pinned generation was retired
        in that window, re-key and retry."""
        for _ in range(4):
            with _span("planner.lookup", "planner") as lsp:
                key = self.plan_key(workload, backend)
                with self._lock:
                    plan = self._cache.get(key)
                    if plan is not None:
                        self._cache.move_to_end(key)
                        self._stats["hits"].inc()
                        lsp.set(hit=True, workload=plan.workload_id)
                        return plan, True
                lsp.set(hit=False)
            try:
                plan = self.compile(self.logical(workload),
                                    self.registry.get(backend), key=key)
            except RetiredGenerationError:
                continue      # pinned generation swapped out of retention
            with self._lock:
                # two threads may compile the same key concurrently (the
                # compile runs unlocked); last-in wins, both plans describe
                # the identical pinned layout so either is correct
                self._stats["misses"].inc()
                self._cache[key] = plan
                while len(self._cache) > self.cache_capacity:
                    self._cache.popitem(last=False)
                    self._stats["evictions"].inc()
            return plan, False
        raise RuntimeError(
            "store layout kept moving during planning (generations retired "
            "faster than they could be pinned); raise max_retired_generations")

    # ------------------------------------------------------- compile stage --
    def compile(self, logical: LogicalPlan, backend: Backend,
                key: Optional[PlanKey] = None) -> PhysicalPlan:
        """LogicalPlan × Backend × the key's pinned layout → PhysicalPlan.

        Datasets are resolved at the generations the key pins (retained by
        the store even across a concurrent swap), never re-read live — the
        cached plan always describes exactly its key.  Raises ``KeyError``
        if a pinned generation left the retention window (the caller
        re-keys)."""
        backend = self.registry.get(backend)
        if key is None:
            key = self.plan_key(logical.workload, backend)
        with _span("planner.compile", "planner",
                   workload=logical.workload_id,
                   backend=backend.name) as csp:
            plan = self._compile_pinned(logical, backend, key)
            csp.set(elided=len(plan.elided), shuffled=len(plan.shuffled))
            return plan

    def _compile_pinned(self, logical: LogicalPlan, backend: Backend,
                        key: PlanKey) -> PhysicalPlan:
        pinned = {name: (self.store.read(name, generation=gen)
                         if gen >= 0 else None)
                  for name, gen, _sig in key.layout}
        g = logical.graph
        steps: List[PlanStep] = []
        elided: List[int] = []
        shuffled: List[int] = []
        match_s = 0.0
        for nid in logical.order:
            node = g.nodes[nid]
            kind = node.kind
            step = PlanStep(nid=nid, kind=kind, label=node.label)
            if kind == "scan":
                step.dataset = node.params["dataset"]
                ds = pinned.get(step.dataset)
                if ds is not None:
                    step.generation = ds.generation
                    step.rows = ds.num_rows
                step.device_relay = backend.device_relay
            elif kind == "partition":
                step.key_node = g.parents(nid)[0]
                step.strategy = node.params.get("strategy", "hash")
                cand = logical.candidates.get(nid)
                step.candidate = cand
                if cand is not None:
                    step.dataset = g.nodes[cand.origin[0]].params.get(
                        "dataset", "")
                # Alg. 4, statically: does the pinned layout of the scanned
                # dataset already realize this node's partitioner?
                stored = pinned.get(step.dataset) if step.dataset else None
                if (cand is not None and self.matching and stored is not None
                        and stored.partitioner is not None):
                    t0 = time.perf_counter()
                    m = partitioning_match(stored.partitioner, step.dataset, g)
                    match_s += time.perf_counter() - t0
                    step.elide = nid in m.partition_nodes
                if step.elide:
                    step.op = "elide"
                    elided.append(nid)
                else:
                    step.device_op = (backend.kernel_shuffle
                                      and step.strategy == "hash")
                    step.op = backend.partition_op(step.strategy)
                    rows = self._static_rows(cand, stored)
                    if step.device_op and rows is not None:
                        from ..data.device_repartition import shape_bucket
                        step.bucket = shape_bucket(rows)
                    shuffled.append(nid)
            elif kind == "join":
                step.projection = node.params.get("projection")
            elif kind == "write":
                step.dataset = node.params["dataset"]
            steps.append(step)
        return PhysicalPlan(key=key, workload=logical.workload,
                            workload_id=logical.workload_id, graph=g,
                            steps=tuple(steps), backend=backend,
                            elided=tuple(elided), shuffled=tuple(shuffled),
                            match_overhead_s=match_s)

    @staticmethod
    def _static_rows(cand: Optional[PartitionerCandidate],
                     stored) -> Optional[int]:
        """Input cardinality of a partition node, when statically known:
        a first-level candidate whose scan→partition chain contains no
        row-changing set op flows exactly the stored dataset's rows."""
        if cand is None or cand.graph is None or stored is None:
            return None
        for n in cand.graph.nodes.values():
            if n.kind in SET_OPS and n.kind not in ("scan", "partition"):
                return None
        return int(stored.num_rows)

    # --------------------------------------------------------- maintenance --
    def cache_stats(self) -> Dict[str, int]:
        with self._lock:
            return {**{k: int(c.value) for k, c in self._stats.items()},
                    "size": len(self._cache)}

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    def invalidate(self, dataset: Optional[str] = None) -> int:
        """Drop cached plans that scan ``dataset`` (all plans if None).
        Generation-keyed lookups already miss stale plans; this frees them
        eagerly (e.g. after a dataset is dropped)."""
        with self._lock:
            if dataset is None:
                n = len(self._cache)
                self._cache.clear()
            else:
                doomed = [k for k in self._cache
                          if any(name == dataset for name, _, _ in k.layout)]
                for k in doomed:
                    del self._cache[k]
                n = len(doomed)
            if n:
                self._stats["invalidations"].inc(n)
            return n
