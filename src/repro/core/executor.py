"""Executor — runs a frozen :class:`~repro.core.planner.PhysicalPlan`.

The second half of the planner/executor split (DESIGN §9).  All per-node
*policy* — candidate extraction, Alg. 4 elision, backend-op binding — was
decided at plan time; the executor is a thin loop over the plan's bound
steps that only carries values, measures stats, and fires observation
hooks.  Node semantics (columnar numpy execution, the worker-local join
restriction, the device-to-device relay) are unchanged from the legacy
``Engine.run`` interpreter and remain bit-identical to it.

The per-candidate measurement pass (selectivity / distinct keys at every
partition node — an ``np.unique`` over the key column) is **gated** behind
observation: it only runs when a history or at least one run hook is
attached, and ``EngineStats.candidate_measure_passes`` counts it so tests
can assert the skip.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..data.device_repartition import device_flat_columns, \
    device_rebucket_full
from ..data.partition_store import RetiredGenerationError
from ..data.skew import HeavyHitterSketch
from ..obs.tracer import span as _span
from .ir import _mix_hash, resolve_fn

Columns = Dict[str, np.ndarray]


class StalePlanError(RuntimeError):
    """A PhysicalPlan was executed against a store whose layout generation
    no longer matches the one the plan was compiled (and its shuffles were
    statically elided) against.  Re-plan — ``Session.run`` and the Engine
    shim do this automatically (:func:`plan_and_execute`); only direct
    ``Executor.execute`` calls see this error."""


@dataclass
class TableVal:
    """A set-valued intermediate: flat columns + per-worker segmentation.

    ``device_columns`` is the device-to-device relay (DESIGN §5): flat
    jax-array copies of (a subset of) ``columns`` left on device by a scan
    of a device-backed dataset or by a device repartition.  Row-preserving
    nodes pass it through; the next device stage (repartition, store write)
    consumes it instead of re-uploading the host columns.  Any row-changing
    op (join, aggregate, filter, flatten, map) drops it."""
    columns: Columns
    counts: np.ndarray                       # (m,) rows per worker segment
    partitioner: Optional[Any] = None        # current PartitionerCandidate
    device_columns: Optional[Columns] = None             # flat jax arrays

    @property
    def num_rows(self) -> int:
        return int(self.counts.sum())

    @property
    def m(self) -> int:
        return int(self.counts.shape[0])

    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.counts)[:-1]]).astype(np.int64)

    def worker_slice(self, w: int) -> Columns:
        o = self.offsets()
        return {k: v[o[w]:o[w] + self.counts[w]] for k, v in self.columns.items()}

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.columns.values()))


@dataclass
class EngineStats:
    """Per-run execution stats (the ExecutionRecord measurement source).

    Kept under its historical name — it is the schema every run hook,
    observer, and benchmark consumes — but now produced by the Executor."""
    shuffles_elided: int = 0
    shuffles_performed: int = 0
    shuffle_bytes: int = 0
    device_repartitions: int = 0     # shuffles routed through the Pallas path
    match_overhead_s: float = 0.0    # plan-time Alg. 4 cost (0 on cache hits)
    stage_latency: Dict[str, float] = field(default_factory=dict)
    wall_s: float = 0.0
    shuffle_s: float = 0.0           # wall time spent inside real shuffles
    input_bytes: int = 0             # bytes scanned from the store
    output_bytes: int = 0            # bytes written back to the store
    planning_s: float = 0.0          # plan/compile wall for this run (0 on hit)
    plan_cache_hit: Optional[bool] = None   # None when run outside a Session
    candidate_measure_passes: int = 0       # measurement-pass executions
    # durable-tier I/O this run caused (DESIGN §10): segment bytes written
    # (autoflushed generations) + read (spill rehydration), and the wall
    # spent on them — the Observer feeds these into the cost model's I/O
    # calibration; zeros on a memory-only store
    storage_io_bytes: int = 0
    storage_io_s: float = 0.0
    storage_rehydrations: int = 0
    # padded-layout accounting over the datasets this run scanned (DESIGN
    # §12): padded = bytes the layouts actually occupy, valid = bytes of
    # real rows.  The gap is what key skew costs; the Observer feeds it to
    # the cost model's padding term.
    padded_bytes: int = 0
    valid_bytes: int = 0
    # the HistoryStore this run's executor appended its record to (None if
    # unobserved) — lets the Observer hook skip a duplicate append when it
    # shares that exact store
    history_logged: Optional[Any] = field(default=None, repr=False)
    # per-candidate runtime stats for this run (ExecutionRecord schema),
    # keyed by candidate signature; None unless the run is being observed
    # (history / run hooks attached) — the np.unique pass isn't free.
    candidate_stats: Optional[Dict[str, Dict[str, float]]] = None

    def modeled_network_s(self, bandwidth: float = 1.25e9) -> float:
        return self.shuffle_bytes / bandwidth


class Executor:
    """Executes PhysicalPlans over a :class:`~repro.data.partition_store.
    PartitionStore`.  Stateless apart from the store/interpret bindings:
    all run-to-run variation lives in the plan (structure) and the store
    (data)."""

    def __init__(self, store, *, interpret: Optional[bool] = None):
        self.store = store
        self.interpret = interpret   # None → auto (interpret mode off-TPU)

    # ------------------------------------------------------------- execute --
    def execute(self, plan, *, history=None, hooks: Tuple[Callable, ...] = (),
                timestamp: Optional[float] = None, workload=None,
                planning_s: float = 0.0, cache_hit: Optional[bool] = None
                ) -> Tuple[Dict[int, Any], "EngineStats"]:
        """Run ``plan``; returns ``(node values, stats)``.

        ``history`` / ``hooks`` turn on the observation pass (per-candidate
        stats at partition nodes) and receive the finished record/stats.
        ``workload`` defaults to the plan's own workload (it is only
        user-visible through hooks and history records).  ``planning_s`` /
        ``cache_hit`` carry the caller's planning cost into the stats so
        hooks observe them."""
        with _span("exec.run", "exec", workload=plan.workload_id,
                   cache_hit=cache_hit) as rsp:
            vals, stats = self._execute(
                plan, history=history, hooks=hooks, timestamp=timestamp,
                workload=workload, planning_s=planning_s,
                cache_hit=cache_hit)
            rsp.set(wall_ms=round(stats.wall_s * 1e3, 3),
                    shuffles=stats.shuffles_performed,
                    elided=stats.shuffles_elided)
            return vals, stats

    def _execute(self, plan, *, history, hooks, timestamp, workload,
                 planning_s, cache_hit) -> Tuple[Dict[int, Any],
                                                 "EngineStats"]:
        workload = workload if workload is not None else plan.workload
        g = plan.graph
        stats = EngineStats()
        observed = history is not None or bool(hooks)
        if observed:
            stats.candidate_stats = {}
        stats.planning_s = planning_s
        stats.plan_cache_hit = cache_hit
        # Alg. 4 ran at plan time; charge it to the run that compiled the plan
        stats.match_overhead_s = 0.0 if cache_hit else plan.match_overhead_s
        # Resolve every scanned dataset BEFORE any step runs (one snapshot,
        # DESIGN §11): a stale plan fails fast with no side effects, so
        # plan_and_execute can re-plan and retry safely even for workloads
        # that write — and once execution starts, the run holds its
        # StoredDataset objects directly, so a concurrent generation flip
        # (or the pinned generation leaving the retention window mid-run)
        # cannot touch an in-flight execution.
        scans: Dict[int, Any] = {}
        for step in plan.steps:
            if step.kind != "scan":
                continue
            if plan.pinned:
                ds = self.store.read(step.dataset)
                if ds.generation != step.generation:
                    # the current pointer moved past the pin; the retained
                    # pinned generation may still resolve — prefer failing
                    # fast so the caller re-plans against the fresh layout
                    raise StalePlanError(
                        f"plan for {plan.workload_id!r} was compiled against "
                        f"{step.dataset}@gen{step.generation} but the store "
                        f"now holds gen{ds.generation}; re-plan (Session.run "
                        "re-keys the plan cache automatically)")
                scans[step.nid] = ds
            else:
                scans[step.nid] = self.store.read(step.dataset)
        io0 = self.store.io_snapshot() if hasattr(self.store,
                                                  "io_snapshot") else {}
        t_start = time.perf_counter()
        vals: Dict[int, Any] = {}

        for step in plan.steps:
            node = g.nodes[step.nid]
            t0 = time.perf_counter()
            kind = step.kind
            parents = g.parents(step.nid)

            with _span("exec." + kind, "exec", nid=step.nid,
                       label=node.label) as ssp:
                if kind == "scan":
                    # the generation resolved by the up-front snapshot
                    # (pinned plans: exactly the layout the elisions were
                    # planned for), held as an object — immune to
                    # concurrent pointer flips
                    ds = scans[step.nid]
                    flat = ds.gather()
                    dev = device_flat_columns(ds) if step.device_relay \
                        else None
                    stats.input_bytes += ds.nbytes
                    stats.padded_bytes += int(ds.padded_bytes)
                    stats.valid_bytes += int(ds.valid_bytes)
                    ssp.set(dataset=step.dataset, generation=ds.generation,
                            rows=ds.num_rows)
                    vals[step.nid] = TableVal(flat, ds.counts.copy(),
                                              ds.partitioner,
                                              device_columns=dev)
                elif kind == "partition":
                    ssp.set(elide=step.elide,
                            path=("elide" if step.elide else
                                  "device" if step.device_op else "host"))
                    vals[step.nid] = self._exec_partition(step, g, vals,
                                                          stats)
                elif kind == "join":
                    vals[step.nid] = self._exec_join(
                        vals[parents[0]], vals[parents[1]], step.projection)
                elif kind == "aggregate":
                    vals[step.nid] = self._exec_aggregate(vals[parents[0]],
                                                          node.params)
                elif kind == "apply":
                    vals[step.nid] = self._exec_map(vals[parents[0]],
                                                    node.params["fn"])
                elif kind == "flatten":
                    vals[step.nid] = self._exec_flatten(vals[parents[0]])
                elif kind == "filter":
                    vals[step.nid] = self._exec_filter(vals[parents[0]],
                                                       vals[parents[1]])
                elif kind == "write":
                    tv: TableVal = vals[parents[0]]
                    cols = {k: v for k, v in tv.columns.items()
                            if k != "__key__"}
                    self.store.write_layout(step.dataset, cols,
                                            tv.counts, tv.partitioner,
                                            device_columns=tv.device_columns)
                    stats.output_bytes += int(sum(v.nbytes
                                                  for v in cols.values()))
                    ssp.set(dataset=step.dataset)
                    vals[step.nid] = tv
                else:
                    # lambda nodes: evaluate over parent values
                    # (columns/TableVal)
                    fn = resolve_fn(node.label, node.params)
                    args = [vals[p].columns if isinstance(vals[p], TableVal)
                            else vals[p] for p in parents]
                    vals[step.nid] = fn(*args)
            stats.stage_latency[f"{step.nid}:{node.label}"] = \
                stats.stage_latency.get(f"{step.nid}:{node.label}", 0.0) + \
                (time.perf_counter() - t0)

        stats.wall_s = time.perf_counter() - t_start
        if io0:
            io1 = self.store.io_snapshot()
            stats.storage_io_bytes = int(
                io1["bytes_written"] - io0["bytes_written"]
                + io1["bytes_read"] - io0["bytes_read"])
            stats.storage_io_s = float(io1["write_s"] - io0["write_s"]
                                       + io1["read_s"] - io0["read_s"])
            stats.storage_rehydrations = int(io1["rehydrations"]
                                             - io0["rehydrations"])
        if history is not None:
            stats.history_logged = history
            history.log_workload(
                workload,
                timestamp=time.time() if timestamp is None else timestamp,
                latency=stats.wall_s,
                input_bytes=float(stats.input_bytes),
                output_bytes=float(stats.output_bytes),
                padded_bytes=float(stats.padded_bytes),
                valid_bytes=float(stats.valid_bytes),
                candidate_stats=stats.candidate_stats or {})
        for hook in hooks:
            hook(workload, stats)
        return vals, stats

    # ------------------------------------------------------- partition step --
    def _exec_partition(self, step, g, vals, stats) -> TableVal:
        """Execute one bound partition step.

        The elide-vs-shuffle decision was frozen at plan time (Alg. 4 run
        statically against the pinned store layout); only the key
        evaluation, the measurement pass (when observed) and the actual
        data movement happen here."""
        table: TableVal = _first_table(vals, g, step.nid)
        key_vals = np.asarray(vals[step.key_node]).reshape(-1)

        # observation (DESIGN §8): per-candidate runtime stats measured at
        # this node feed the auto-logged ExecutionRecord.  Gated: without a
        # history or run hook the np.unique pass is skipped entirely.
        if stats.candidate_stats is not None and step.candidate is not None:
            stats.candidate_measure_passes += 1
            _record_candidate_stats(stats.candidate_stats,
                                    step.candidate.signature(), table,
                                    key_vals)

        if step.elide:
            stats.shuffles_elided += 1
            out = TableVal(dict(table.columns), table.counts.copy(),
                           table.partitioner,
                           device_columns=table.device_columns)
            out.columns["__key__"] = key_vals
            return out                       # layout already correct

        # shuffle: hash the key column, re-bucket every column
        t_sh = time.perf_counter()
        if step.device_op and key_vals.size:
            # DESIGN §5: one jitted plan — fused hash + histogram +
            # counting-sort permutation + packed gather; upstream device
            # flats (scan of a device store) feed it without re-upload
            res = device_rebucket_full(table.columns, key_vals, table.m,
                                       interpret=self.interpret,
                                       device_columns=table.device_columns)
            stats.shuffles_performed += 1
            stats.device_repartitions += 1
            stats.shuffle_bytes += int(table.nbytes() * (table.m - 1)
                                       / table.m)
            stats.shuffle_s += time.perf_counter() - t_sh
            return TableVal(res.columns, res.counts,
                            step.candidate or table.partitioner,
                            device_columns=res.device_columns)
        if step.strategy == "range":
            lo, hi = key_vals.min(), key_vals.max()
            width = max((hi - lo) / table.m, 1e-9)
            pids = np.clip(((key_vals - lo) / width).astype(np.int64),
                           0, table.m - 1)
        else:
            pids = np.asarray(_mix_hash(key_vals)).astype(np.int64) % table.m
        order = np.argsort(pids, kind="stable")
        counts = np.bincount(pids, minlength=table.m).astype(np.int64)
        new_cols = {k: v[order] for k, v in table.columns.items()}
        new_cols["__key__"] = key_vals[order]
        stats.shuffles_performed += 1
        stats.shuffle_bytes += int(table.nbytes() * (table.m - 1) / table.m)
        stats.shuffle_s += time.perf_counter() - t_sh
        return TableVal(new_cols, counts, step.candidate or table.partitioner)

    # ------------------------------------------------------------- join node --
    def _exec_join(self, left: TableVal, right: TableVal,
                   projection: Optional[Callable]) -> TableVal:
        out_segments: List[Columns] = []
        counts = np.zeros(left.m, np.int64)
        for w in range(left.m):
            lc, rc = left.worker_slice(w), right.worker_slice(w)
            lk = lc.pop("__key__")
            rk = rc.pop("__key__")
            if lk.size == 0 or rk.size == 0:
                continue
            sidx = np.argsort(rk, kind="stable")
            rk_sorted = rk[sidx]
            pos = np.searchsorted(rk_sorted, lk)
            pos = np.clip(pos, 0, rk_sorted.size - 1)
            hit = rk_sorted[pos] == lk
            ridx = sidx[pos[hit]]
            lsel = np.nonzero(hit)[0]
            seg: Columns = {k: v[lsel] for k, v in lc.items()}
            for k, v in rc.items():
                seg[f"r_{k}" if k in seg else k] = v[ridx]
            if projection is not None:
                seg = projection(seg)
            counts[w] = len(lsel)
            out_segments.append(seg)
        if out_segments:
            keys = out_segments[0].keys()
            cols = {k: np.concatenate([s[k] for s in out_segments])
                    for k in keys}
        else:
            cols = {}
        return TableVal(cols, counts, left.partitioner)

    # -------------------------------------------------------- aggregate node --
    def _exec_aggregate(self, table: TableVal, params) -> TableVal:
        reducer = params.get("reducer", "sum")
        fn = params.get("fn")
        if fn is not None:
            return TableVal(fn(table.columns), np.array([1] * table.m),
                            table.partitioner)
        # keyed aggregation: key is the repartition key from the upstream
        # partition node ("__key__"); values are all other columns
        out_segs: List[Columns] = []
        counts = np.zeros(table.m, np.int64)
        for w in range(table.m):
            seg = table.worker_slice(w)
            if not seg or len(next(iter(seg.values()))) == 0:
                continue
            key = seg.get("__key__", seg.get("key"))
            uk, inv = np.unique(key, return_inverse=True)
            agg: Columns = {"key": uk}
            for k, v in seg.items():
                if k in ("key", "__key__"):
                    continue
                acc = np.zeros((len(uk),) + v.shape[1:], np.float64)
                np.add.at(acc, inv, v)
                if reducer == "mean":
                    cnt = np.bincount(inv, minlength=len(uk)).astype(np.float64)
                    acc = acc / cnt.reshape((-1,) + (1,) * (acc.ndim - 1))
                agg[k] = acc.astype(v.dtype)
            counts[w] = len(uk)
            out_segs.append(agg)
        if out_segs:
            cols = {k: np.concatenate([s[k] for s in out_segs])
                    for k in out_segs[0]}
        else:
            cols = {}
        return TableVal(cols, counts, table.partitioner)

    # ------------------------------------------------------------- map/flatten --
    def _exec_map(self, table: TableVal, fn: Optional[Callable]) -> TableVal:
        if fn is None:
            return table
        return TableVal(fn(table.columns), table.counts.copy(),
                        table.partitioner)

    def _exec_flatten(self, table: TableVal) -> TableVal:
        fan = None
        cols: Columns = {}
        for k, v in table.columns.items():
            if v.ndim >= 2:
                fan = v.shape[1]
                cols[k] = v.reshape((-1,) + v.shape[2:])
        if fan is None:
            return table
        for k, v in table.columns.items():
            if v.ndim == 1:
                cols[k] = np.repeat(v, fan)
        return TableVal(cols, table.counts * fan, table.partitioner)

    def _exec_filter(self, table: TableVal, pred: np.ndarray) -> TableVal:
        pred = np.asarray(pred).reshape(-1).astype(bool)
        o = table.offsets()
        counts = np.array([int(pred[o[w]:o[w] + table.counts[w]].sum())
                           for w in range(table.m)], np.int64)
        cols = {k: v[pred] for k, v in table.columns.items()}
        return TableVal(cols, counts, table.partitioner)


def plan_and_execute(planner, executor: Executor, workload, backend, *,
                     history=None, hooks: Tuple[Callable, ...] = (),
                     timestamp: Optional[float] = None,
                     max_replans: int = 4):
    """The shared run path behind ``Session.run`` and the Engine shim:
    plan (cached) + execute, transparently re-planning when a concurrent
    layout swap (e.g. a background Autopilot repartition) lands between
    the cache lookup and the executor's up-front generation check.

    Returns ``(vals, stats, plan)``.  The retry is side-effect-free:
    ``Executor.execute`` resolves and validates every scanned generation
    before running any step, so a stale plan (or a pin that left the
    bounded retention window under sustained background flips —
    ``RetiredGenerationError``) fails before any value is computed or
    written.  Together with the executor's one-snapshot read this makes a
    background Autopilot flip invisible to callers: they only ever see a
    complete result computed against one consistent layout (DESIGN §11).
    """
    for attempt in range(max_replans + 1):
        t0 = time.perf_counter()
        try:
            plan, hit = planner.physical(workload, backend)
            planning_s = time.perf_counter() - t0
            vals, stats = executor.execute(
                plan, history=history, hooks=hooks, timestamp=timestamp,
                workload=workload, planning_s=planning_s, cache_hit=hit)
            return vals, stats, plan
        except (StalePlanError, RetiredGenerationError):
            # the store moved under us; the next physical() re-keys
            # against the new generations and compiles a fresh plan
            if attempt == max_replans:
                raise


def _record_candidate_stats(out: Dict[str, Dict[str, float]], sig: str,
                            table: TableVal, key_vals: np.ndarray) -> None:
    """Measure the ExecutionRecord candidate-stat schema at a partition
    node.  Two partition nodes in one run can share a (structural)
    signature; merging mirrors features.py aggregation — max selectivity,
    min distinct keys — so per-run stats compose like per-group ones."""
    object_bytes = float(table.nbytes())
    key_bytes = float(key_vals.nbytes)
    # heavy-hitter sketch over the key column (DESIGN §12): a lower bound
    # on the hottest key's share, riding the same observation pass — the
    # Autopilot's salt trigger.  Merge-by-max below is correct for it.
    st = {
        "selectivity": key_bytes / object_bytes if object_bytes else 0.0,
        "distinct_keys": float(np.unique(key_vals).size),
        "num_objects": float(table.num_rows),
        "key_bytes": key_bytes,
        "object_bytes": object_bytes,
        "max_key_fraction": HeavyHitterSketch(k=8).update(key_vals)
        .max_fraction(),
    }
    cur = out.get(sig)
    if cur is None:
        out[sig] = st
        return
    for k, v in st.items():
        cur[k] = min(cur[k], v) if k == "distinct_keys" else max(cur[k], v)


def _first_table(vals, g, nid):
    for p in g.parents(nid):
        v = vals.get(p)
        if isinstance(v, TableVal):
            return v
        sub = _first_table(vals, g, p)
        if sub is not None:
            return sub
    return None
