"""Bridge: Lachesis partitionings ⇄ JAX shardings.

A persistent partitioning over ``m`` workers maps onto a TPU mesh as a
``NamedSharding`` whose leading (worker) axis is laid out over the data axes.
The *match ⇒ elide-shuffle* decision becomes: if a consumer step function's
required input ``PartitionSpec`` equals the stored one, XLA inserts **no
resharding collective** for that operand — verified structurally in the
dry-run by counting collectives in the lowered HLO.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .partitioner import PartitionerCandidate


def sharding_for(mesh: Mesh, candidate: Optional[PartitionerCandidate],
                 data_axes: Tuple[str, ...] = ("data",),
                 extra_dims: int = 0) -> NamedSharding:
    """Sharding of a stored dataset's ``(m, capacity, ...)`` layout.

    Keyed/rr/random partitionings all distribute rows across workers, so the
    worker axis is sharded over the data mesh axes; what differs is the
    *assignment* of rows to workers, which lives in the partitioner, not the
    sharding.  Trailing dims are replicated unless the caller shards them.
    """
    spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
             *([None] * (1 + extra_dims)))
    return NamedSharding(mesh, spec)


def specs_match(a: P, b: P) -> bool:
    """Structural PartitionSpec equality modulo trailing Nones — the
    sharding-level analogue of Alg. 4's signature equality."""
    la, lb = list(a), list(b)
    n = max(len(la), len(lb))
    la += [None] * (n - len(la))
    lb += [None] * (n - len(lb))
    return la == lb


def would_elide_collective(stored: P, required: P) -> bool:
    """True ⇒ consuming the operand needs no resharding collective."""
    return specs_match(stored, required)
