"""Bridge: Lachesis partitionings ⇄ JAX shardings.

A persistent partitioning over ``m`` workers maps onto a TPU mesh as a
``NamedSharding`` whose leading (worker) axis is laid out over the data axes.
The *match ⇒ elide-shuffle* decision becomes: if a consumer step function's
required input ``PartitionSpec`` equals the stored one, XLA inserts **no
resharding collective** for that operand — verified structurally in the
dry-run by counting collectives in the lowered HLO.

:func:`device_put_dataset` closes the loop for the device-resident
repartition path (DESIGN §5): a store dataset's ``(m, capacity, ...)``
columns are placed with the leading worker axis sharded over the mesh, so a
worker-local consumer reads only its own shard.  Columns that are already
device-resident (device store writes, d2d repartition outputs) are re-placed
device-to-device; ``PartitionStore.repartition(..., mesh=...)`` uses this so
repartitioned datasets stay mesh-placed.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .partitioner import PartitionerCandidate
from ..data.device_repartition import dtype_roundtrips


def sharding_for(mesh: Mesh, candidate: Optional[PartitionerCandidate],
                 data_axes: Tuple[str, ...] = ("data",),
                 extra_dims: int = 0) -> NamedSharding:
    """Sharding of a stored dataset's ``(m, capacity, ...)`` layout.

    Keyed/rr/random partitionings all distribute rows across workers, so the
    worker axis is sharded over the data mesh axes; what differs is the
    *assignment* of rows to workers, which lives in the partitioner, not the
    sharding.  Trailing dims are replicated unless the caller shards them.
    """
    spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
             *([None] * (1 + extra_dims)))
    return NamedSharding(mesh, spec)


def specs_match(a: P, b: P) -> bool:
    """Structural PartitionSpec equality modulo trailing Nones — the
    sharding-level analogue of Alg. 4's signature equality."""
    la, lb = list(a), list(b)
    n = max(len(la), len(lb))
    la += [None] * (n - len(la))
    lb += [None] * (n - len(lb))
    return la == lb


def would_elide_collective(stored: P, required: P) -> bool:
    """True ⇒ consuming the operand needs no resharding collective."""
    return specs_match(stored, required)


def device_put_dataset(mesh: Mesh, ds,
                       data_axes: Tuple[str, ...] = ("data",)):
    """Place a StoredDataset's padded columns on ``mesh``, worker axis
    sharded — the persistent partitioning made physical (DESIGN §5).

    Returns a new ``StoredDataset`` whose round-trippable columns are jax
    arrays committed to ``sharding_for(mesh, ds.partitioner)``; 64-bit
    columns (unrepresentable with x64 disabled) stay host-resident.  The
    worker count ``m`` must divide evenly over the data mesh axes.
    """
    from ..data.partition_store import StoredDataset
    extent = int(np.prod([mesh.shape[a] for a in data_axes]))
    if ds.num_workers % extent:
        raise ValueError(
            f"m={ds.num_workers} not divisible by mesh data extent {extent}")
    # A bucketed (CapacityMap) layout has no leading worker axis — its flat
    # slot axis is not evenly divisible across the mesh — so its columns are
    # placed on device unsharded (replicated); worker-locality for bucketed
    # datasets comes back when the slot ranges align with node boundaries
    # (ROADMAP item 2).
    bucketed = getattr(ds, "capacity_map", None) is not None
    cols = {}
    for k, v in ds.columns.items():
        # already-device-resident columns (device write / d2d repartition
        # output) are re-placed device-to-device — no host round-trip
        if isinstance(v, jax.Array):
            if bucketed:
                cols[k] = jax.device_put(v)
                continue
            sh = sharding_for(mesh, ds.partitioner, data_axes,
                              extra_dims=v.ndim - 2)
            cols[k] = jax.device_put(v, sh)
            continue
        v_np = np.asarray(v)
        if dtype_roundtrips(v_np.dtype):
            if bucketed:
                cols[k] = jax.device_put(v_np)
                continue
            sh = sharding_for(mesh, ds.partitioner, data_axes,
                              extra_dims=v_np.ndim - 2)
            cols[k] = jax.device_put(v_np, sh)
        else:
            cols[k] = v_np
    return StoredDataset(name=ds.name, columns=cols, counts=ds.counts,
                         partitioner=ds.partitioner, num_rows=ds.num_rows,
                         nbytes=ds.nbytes, created_at=ds.created_at,
                         generation=ds.generation,
                         capacity_map=getattr(ds, "capacity_map", None))
