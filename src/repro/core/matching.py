"""Partitioning ⇄ workload matching (paper §3.2, Alg. 4).

Subgraph isomorphism is NP-complete in general; the two-terminal property of
partitioner subgraphs lets us match by *path-signature sets*: the stored
partitioning ``f_D`` matches a candidate subgraph ``IG^(s_D, p_i)`` iff the
sorted multiset of root→leaf path signatures is equal.  On a match the
consumer's shuffle (the subgraph ending at ``p_i``) is elided — on TPU, the
corresponding all-to-all/all-gather never enters the lowered program.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from .ir import IRGraph
from .partitioner import PartitionerCandidate, search, merge


@dataclass
class MatchResult:
    matched: bool
    partition_nodes: List[int]      # partition nodes in consumer IR whose
                                    # shuffle can be elided
    checked: int = 0                # candidate subgraphs inspected


def partitioning_match(f_D: Optional[PartitionerCandidate], dataset: str,
                       a: IRGraph) -> MatchResult:
    """Alg. 4: find all subgraphs of consumer IR ``a`` isomorphic to the
    stored partitioning ``f_D`` of ``dataset``."""
    if f_D is None or not f_D.is_keyed:
        return MatchResult(False, [])
    ssset_D = f_D.signature_set()
    s_D = a.find_scanner(dataset)
    if s_D is None:
        return MatchResult(False, [])

    matched_nodes: List[int] = []
    checked = 0
    # candidate isomorphic subgraphs = merged two-terminal subgraphs from the
    # same scan node; reuse Alg. 1+2 to construct IG^(s_D, p_i)
    for cand in merge(a, search(a, s_D)):
        checked += 1
        # the strategy label participates in the signature via the partition
        # node token, so hash vs range partitionings never cross-match
        if cand.signature_set() == ssset_D:
            matched_nodes.append(cand.origin[1])
    return MatchResult(bool(matched_nodes), matched_nodes, checked)


def plan_shuffles(a: IRGraph, stored: dict) -> Tuple[List[int], List[int]]:
    """Query-scheduler hook: split the consumer IR's partition nodes into
    (elided, required) given ``stored: dataset -> PartitionerCandidate``.

    A partition node is elided iff it terminates a candidate whose signature
    matches the persistent partitioning of the dataset it reads from.
    """
    elided: List[int] = []
    for dataset, f_D in stored.items():
        res = partitioning_match(f_D, dataset, a)
        elided.extend(res.partition_nodes)
    required = [p for p in a.partition_nodes if p not in set(elided)]
    return sorted(set(elided)), sorted(required)
