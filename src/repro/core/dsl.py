"""Tracing DSL embedded in Python — the paper's lambda-calculus embedding.

Users write UDF-centric workloads against :class:`Col` handles; tracing
builds the :class:`~repro.core.ir.IRGraph`.  Example (paper Listing 1/2):

    wl = Workload("author-integrator")
    reviews = wl.scan("reviews")
    authors = wl.scan("authors")
    j = wl.join(reviews, authors,
                left_key=reviews.parse("json")["author"],
                right_key=authors.parse("csv")["author"])
    wl.write(j, "integrated")

The join lowers to ``partition(left_key) + partition(right_key) + join`` —
exactly the shape from which Alg. 1/2 extract partitioner candidates.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from .ir import IRGraph


class Col:
    """A handle to an IR node producing a per-object value."""

    def __init__(self, wl: "Workload", nid: int):
        self._wl = wl
        self._nid = nid

    # lambda abstraction: member access
    def __getitem__(self, name: str) -> "Col":
        return self._wl._unary(f"attr:{name}", self)

    def attr(self, name: str) -> "Col":
        return self[name]

    def parse(self, fmt: str) -> "Col":
        return self._wl._unary(f"parse:{fmt}", self)

    def func(self, name: str) -> "Col":
        return self._wl._unary(f"func:{name}", self)

    def apply(self, fn: Callable, tag: str) -> "Col":
        return self._wl._unary(f"opaque:{tag}", self, params={"fn": fn})

    def _bin(self, op: str, other: Any) -> "Col":
        other = self._wl.lit(other) if not isinstance(other, Col) else other
        return self._wl._binary(f"binop:{op}", self, other)

    def __eq__(self, o):  # type: ignore[override]
        return self._bin("==", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("!=", o)

    def __lt__(self, o):
        return self._bin("<", o)

    def __gt__(self, o):
        return self._bin(">", o)

    def __le__(self, o):
        return self._bin("<=", o)

    def __ge__(self, o):
        return self._bin(">=", o)

    def __add__(self, o):
        return self._bin("+", o)

    def __sub__(self, o):
        return self._bin("-", o)

    def __mul__(self, o):
        return self._bin("*", o)

    def __truediv__(self, o):
        return self._bin("/", o)

    def __mod__(self, o):
        return self._bin("%", o)

    def __hash__(self):
        return id(self)


class SetHandle(Col):
    """Handle to a set-valued node (scan / join / aggregate output...)."""


class Workload:
    """A traced workload; owns one IRGraph."""

    def __init__(self, app_id: str):
        self.app_id = app_id
        self.graph = IRGraph()

    # -- node helpers ---------------------------------------------------------
    def _unary(self, label: str, x: Col, params: Optional[Dict] = None) -> Col:
        nid = self.graph.add_node(label, params)
        self.graph.add_edge(x._nid, nid)
        return Col(self, nid)

    def _binary(self, label: str, a: Col, b: Col) -> Col:
        nid = self.graph.add_node(label)
        self.graph.add_edge(a._nid, nid)
        self.graph.add_edge(b._nid, nid)
        return Col(self, nid)

    def lit(self, value: Any) -> Col:
        nid = self.graph.add_node(f"literal:{value!r}", {"value": value})
        return Col(self, nid)

    # -- set-based operators ----------------------------------------------------
    def scan(self, dataset: str) -> SetHandle:
        nid = self.graph.add_node("scan", {"dataset": dataset})
        return SetHandle(self, nid)

    def partition(self, key: Col, strategy: str = "hash") -> SetHandle:
        nid = self.graph.add_node("partition", {"strategy": strategy})
        self.graph.add_edge(key._nid, nid)
        return SetHandle(self, nid)

    def join(self, left: SetHandle, right: SetHandle, *, left_key: Col,
             right_key: Col, strategy: str = "hash",
             projection: Optional[Callable] = None,
             tag: str = "join") -> SetHandle:
        """Hash join: lowered to partition(left_key) ⋈ partition(right_key),
        the IR shape of Fig. 2 in the paper (after join-strategy selection)."""
        lp = self.partition(left_key, strategy)
        rp = self.partition(right_key, strategy)
        nid = self.graph.add_node("join", {"projection": projection,
                                           "tag": tag})
        self.graph.add_edge(lp._nid, nid)
        self.graph.add_edge(rp._nid, nid)
        return SetHandle(self, nid)

    def aggregate(self, x: SetHandle, *, key: Optional[Col] = None,
                  reducer: str = "sum",
                  fn: Optional[Callable] = None) -> SetHandle:
        """Keyed aggregation; a keyed aggregate also repartitions by key, so
        it contributes a partition node (shuffle) like a join side does."""
        if key is not None:
            x = self.partition(key, "hash")
        nid = self.graph.add_node("aggregate", {"reducer": reducer, "fn": fn})
        self.graph.add_edge(x._nid, nid)
        return SetHandle(self, nid)

    def filter(self, x: SetHandle, pred: Col) -> SetHandle:
        nid = self.graph.add_node("filter")
        self.graph.add_edge(x._nid, nid)
        self.graph.add_edge(pred._nid, nid)
        return SetHandle(self, nid)

    def map(self, x: SetHandle, fn: Callable, tag: str) -> SetHandle:
        nid = self.graph.add_node("apply", {"fn": fn, "tag": tag})
        self.graph.add_edge(x._nid, nid)
        return SetHandle(self, nid)

    def flatten(self, x: SetHandle) -> SetHandle:
        nid = self.graph.add_node("flatten")
        self.graph.add_edge(x._nid, nid)
        return SetHandle(self, nid)

    def write(self, x: SetHandle, dataset: str) -> SetHandle:
        nid = self.graph.add_node("write", {"dataset": dataset})
        self.graph.add_edge(x._nid, nid)
        return SetHandle(self, nid)

    # -- convenience --------------------------------------------------------------
    def signature(self) -> str:
        return self.graph.graph_signature()


# ---------------------------------------------------------------------------
# Canned workloads used throughout tests/benchmarks (paper §5.1)
# ---------------------------------------------------------------------------

def reddit_loader(name: str, dataset: str, out: str, fmt: str) -> Workload:
    """Producer: load (parse) a raw file set and write to storage."""
    wl = Workload(name)
    raw = wl.scan(dataset)
    parsed = wl.map(raw, fn=lambda x: x, tag=f"parse_{fmt}")
    wl.write(parsed, out)
    return wl


def author_integrator() -> Workload:
    """Paper Listing 1: join reviews (json) with authors (csv) on author."""
    wl = Workload("author-integrator")
    subs = wl.scan("submissions")
    auth = wl.scan("authors")
    j = wl.join(subs, auth,
                left_key=subs.parse("json")["author"],
                right_key=auth.parse("csv")["author"],
                tag="author_join")
    wl.write(j, "integrated")
    return wl


def pagerank_iteration() -> Workload:
    """Paper §5.2.2: join Pages with Ranks on url, aggregate new ranks."""
    wl = Workload("pagerank-iter")
    pages = wl.scan("pages")
    ranks = wl.scan("ranks")
    j = wl.join(pages, ranks,
                left_key=pages["url"], right_key=ranks["url"],
                tag="pr_join")
    contrib = wl.flatten(wl.map(j, fn=None, tag="emit_contribs"))
    agg = wl.aggregate(contrib, key=contrib["url"], reducer="sum")
    new_ranks = wl.map(agg, fn=None, tag="finish_ranks")  # damping + rename
    wl.write(new_ranks, "ranks")
    return wl


def matmul_workload(transpose_left: bool = False) -> Workload:
    """Paper §5.2.3: blocked matmul — join left blocks (col id) with right
    blocks (row id), multiply, aggregate partial products by (row, col)."""
    wl = Workload("block-matmul" + ("-gram" if transpose_left else ""))
    lhs = wl.scan("lhs_blocks")
    rhs = wl.scan("rhs_blocks")
    lkey = lhs["row_id"] if transpose_left else lhs["col_id"]
    j = wl.join(lhs, rhs, left_key=lkey, right_key=rhs["row_id"],
                tag="block_join")
    prods = wl.map(j, fn=None, tag="mkl_gemm")
    out = wl.aggregate(prods, key=prods["out_block_id"], reducer="sum")
    wl.write(out, "product_blocks")
    return wl
