"""``lachesis`` — the stable public API surface of the reproduction.

A thin namespace over :mod:`repro`:

    import lachesis

    sess = lachesis.Session(num_workers=8, backend="device")
    sess.write("submissions", subs)
    res = sess.run(workload)
    print(sess.explain(workload))

Everything here is re-exported from ``repro.api`` / ``repro.core`` /
``repro.service``; the implementation package keeps its historical name,
this module is the import users program against.
"""

from repro.api import RunResult, Session, StalePlanError, UnknownBackendError
from repro.cluster import ClusterConfig, RebalanceAborted
from repro.core.backends import (Backend, BackendRegistry, REGISTRY,
                                 backend_names, resolve_backend)
from repro.core.dsl import Workload
from repro.core.executor import EngineStats as RunStats
from repro.core.planner import LogicalPlan, PhysicalPlan, Planner

__all__ = [
    "Session", "RunResult", "RunStats", "Workload",
    "LogicalPlan", "PhysicalPlan", "Planner",
    "Backend", "BackendRegistry", "REGISTRY", "backend_names",
    "resolve_backend", "UnknownBackendError", "StalePlanError",
    "ClusterConfig", "RebalanceAborted",
]


def autopilot(session, **kw):
    """Convenience: attach an online storage optimizer to ``session``."""
    return session.autopilot(**kw)
