"""Race-hunting tests for the serving tier (DESIGN §11).

One shared PartitionStore, many live clients, a background writer flipping
layout generations — the invariant throughout is *serial equivalence*:
every concurrent result must be bit-identical to the same workload run
serially, no errors, no partial layouts observed.  Covers:

* 16 concurrent clients vs one store while generations flip underneath
  (both a raw repartition loop and a real background Autopilot);
* coalescing: identical queued requests share one execution, and a
  generation flip splits coalescing groups (never crosses layouts);
* plan-cache thrash: capacity-2 planner + ShufflePlan caches under
  concurrent distinct workloads stay correct and bounded;
* tenant isolation: one tenant's budget exhaustion or failing UDF cannot
  fail another tenant's traffic;
* hypothesis-driven reader/writer/evictor interleavings over a durable
  budget-bound store.
"""

import tempfile
import threading

import numpy as np
import pytest

import repro.data.device_repartition as dr
from repro.api import Session
from repro.core.dsl import Workload
from repro.core.partitioner import enumerate_candidates
from repro.data.partition_store import PartitionStore
from repro.service import (AdmissionError, TenantBudgetError,
                           aggregate_result, drift_tables)
from repro.service.observer import LogicalClock


# ---------------------------------------------------------------------------
# read-only variants of the drift mix (no write node => coalescable)
# ---------------------------------------------------------------------------

def q_orderkey_ro() -> Workload:
    wl = Workload("q-orderkey-ro")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    wl.aggregate(j, key=j["odate"], reducer="sum")
    return wl


def q_partkey_ro() -> Workload:
    wl = Workload("q-partkey-ro")
    li = wl.scan("lineitem")
    pt = wl.scan("part")
    j = wl.join(li, pt, left_key=li["partkey"], right_key=pt["partkey"],
                tag="li_part")
    wl.aggregate(j, key=j["size"], reducer="sum")
    return wl


def _seed_session(max_retired_generations: int = 2, **kw) -> Session:
    store = PartitionStore(num_workers=4, backend="host",
                           max_retired_generations=max_retired_generations)
    sess = Session(store, **kw)
    for name, data in drift_tables(n_lineitem=3000, n_orders=800,
                                   n_parts=200).items():
        sess.write(name, data)
    return sess


def _expected(sess: Session):
    """Serial baselines — layout-independent by construction (integer-
    valued float payloads, canonical key-sorted aggregate)."""
    return {
        "ok": aggregate_result(sess.run(q_orderkey_ro()).values,
                               q_orderkey_ro()),
        "pk": aggregate_result(sess.run(q_partkey_ro()).values,
                               q_partkey_ro()),
    }


def _assert_same(got, want):
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
        assert got[k].dtype == want[k].dtype


def _lineitem_candidates(store: PartitionStore):
    """Two genuinely different keyed layouts for lineitem (orderkey via
    the Q04 graph, partkey via the Q17 graph) — alternating them flips
    generations AND changes partitioner signatures/plan keys."""
    ok = enumerate_candidates(q_orderkey_ro().graph, "lineitem")[0]
    pk = enumerate_candidates(q_partkey_ro().graph, "lineitem")[0]
    return [ok, pk]


# ---------------------------------------------------------------------------
# the headline stress: 16 clients, background flips, serial equivalence
# ---------------------------------------------------------------------------

def test_sixteen_clients_bit_identical_under_background_flips():
    # generous retention: queued plans pin generations while the flipper
    # publishes new ones; pins must stay resolvable for the whole queue
    sess = _seed_session(max_retired_generations=16)
    want = _expected(sess)
    front = sess.serve(max_workers=16, max_queue=256)

    cands = _lineitem_candidates(sess.store)
    stop = threading.Event()
    flips = []

    def flipper():
        i = 0
        while not stop.is_set():
            cand = cands[i % 2]
            new, _ = sess.store.repartition(sess.store.read("lineitem"),
                                            cand, swap=True)
            flips.append(new.generation)
            i += 1

    errors = []

    def client(cid):
        try:
            for j in range(6):
                ro = q_orderkey_ro() if (cid + j) % 2 else q_partkey_ro()
                key = "ok" if (cid + j) % 2 else "pk"
                # half the traffic opts out of coalescing so executions
                # genuinely overlap; the other half exercises sharing
                res = front.run(ro, coalesce=bool(cid % 2), timeout=120,
                                block=True)
                _assert_same(aggregate_result(res.values, ro), want[key])
        except BaseException as e:      # noqa: BLE001
            errors.append((cid, e))

    flip_t = threading.Thread(target=flipper, daemon=True)
    flip_t.start()
    clients = [threading.Thread(target=client, args=(c,)) for c in range(16)]
    for t in clients:
        t.start()
    for t in clients:
        t.join(timeout=300)
    stop.set()
    flip_t.join(timeout=60)

    assert not errors, f"concurrent serves failed: {errors[:3]}"
    assert len(flips) >= 2, "flipper never flipped — stress was vacuous"
    st = front.stats()
    assert st["failed"] == 0
    assert st["completed"] >= 16       # >= one execution per client batch
    front.close()


def test_serving_with_real_background_autopilot():
    """The integration the tier exists for: live traffic while an attached
    Autopilot autonomously observes, decides and swaps layouts."""
    sess = _seed_session(max_retired_generations=16)
    want = _expected(sess)
    ap = sess.autopilot(clock=LogicalClock())
    front = sess.serve(max_workers=8, max_queue=128)

    # prime the history so the optimizer has something to act on
    for _ in range(3):
        front.run(q_orderkey_ro(), timeout=120, block=True)
    ap.start(period_s=0.02)
    try:
        errors = []

        def client(cid):
            try:
                for _ in range(4):
                    res = front.run(q_orderkey_ro(), coalesce=False,
                                    timeout=120, block=True)
                    _assert_same(aggregate_result(res.values,
                                                  q_orderkey_ro()),
                                 want["ok"])
            except BaseException as e:  # noqa: BLE001
                errors.append((cid, e))

        clients = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=300)
        assert not errors, f"serves failed under autopilot: {errors[:3]}"
    finally:
        ap.stop()
        front.close()
    # the autopilot actually moved the layout at least once
    applied = [d for r in ap.optimizer.reports for d in r.applied]
    assert applied, "autopilot never applied a decision — stress vacuous"
    assert front.stats()["failed"] == 0


# ---------------------------------------------------------------------------
# coalescing semantics
# ---------------------------------------------------------------------------

def test_coalescing_shares_one_execution():
    sess = _seed_session()
    want = _expected(sess)["ok"]

    # one worker held on a gated filler keeps the coalescing leader
    # *queued* while the followers arrive — the pile-on is deterministic
    front = sess.serve(max_workers=1, max_queue=64)
    gate = threading.Event()
    filler = Workload("filler")
    x = filler.scan("lineitem")
    filler.map(x, lambda c: (gate.wait(60), {"k": c["orderkey"]})[1],
               tag="gated")
    f = front.submit(filler)
    wl = q_orderkey_ro()
    tickets = [front.submit(wl) for _ in range(12)]
    gate.set()
    f.result(120)
    results = [t.result(120) for t in tickets]
    assert len({id(t) for t in tickets}) == 1, \
        "identical queued requests must share one ticket"
    for r in results:
        _assert_same(aggregate_result(r.values, wl), want)
    st = front.stats()
    assert st["coalesced"] == 11 and st["admitted"] == 2
    front.close()


def test_generation_flip_splits_coalescing_groups():
    sess = _seed_session()
    front = sess.serve(max_workers=4, max_queue=64)
    wl = q_orderkey_ro()
    t1 = front.submit(wl)
    t1.result(120)

    cand = _lineitem_candidates(sess.store)[0]
    sess.store.repartition(sess.store.read("lineitem"), cand, swap=True)

    t2 = front.submit(wl)
    t2.result(120)
    # the plan-cache key pins layout generations: a flip between the two
    # submissions must produce distinct coalescing identities
    assert t1.key != t2.key
    _assert_same(aggregate_result(t2.result().values, wl),
                 aggregate_result(t1.result().values, wl))
    front.close()


def test_write_workloads_never_coalesce():
    sess = _seed_session()
    front = sess.serve(max_workers=4, max_queue=64)
    wl = Workload("writer")
    x = wl.scan("lineitem")
    agg = wl.aggregate(x, key=x["orderkey"], reducer="sum")
    wl.write(agg, "out")
    t1 = front.submit(wl)
    t2 = front.submit(wl)
    t1.result(120)
    t2.result(120)
    assert t1 is not t2 and t1.key is None and t2.key is None
    assert front.stats()["coalesced"] == 0
    front.close()


# ---------------------------------------------------------------------------
# admission / backpressure
# ---------------------------------------------------------------------------

def test_admission_queue_full_rejects_then_recovers():
    sess = _seed_session()
    front = sess.serve(max_workers=1, max_queue=1, coalesce=False)
    gate = threading.Event()

    def slow(wid):
        wl = Workload(f"slow-{wid}")
        x = wl.scan("lineitem")
        wl.map(x, lambda c: (gate.wait(60), {"k": c["orderkey"]})[1],
               tag="gated")
        return wl

    a = front.submit(slow(0))     # running, parked on the gate
    b = front.submit(slow(1))     # occupies the one waiting slot
    with pytest.raises(AdmissionError):
        front.submit(slow(2))     # both slots held -> backpressure
    gate.set()
    a.result(120)
    b.result(120)
    # slots drained -> admission works again
    front.submit(slow(3)).result(120)
    st = front.stats()
    assert st["rejected"] == 1 and st["failed"] == 0
    front.close()


# ---------------------------------------------------------------------------
# plan-cache thrash: tiny caches, concurrent distinct workloads
# ---------------------------------------------------------------------------

def test_plan_cache_thrash_capacity_two():
    sess = _seed_session(plan_cache_capacity=2)
    want = _expected(sess)
    old_cap = dr.plan_cache_capacity()
    dr.set_plan_cache_capacity(2)
    try:
        front = sess.serve(max_workers=8, max_queue=128)
        errors = []

        def client(cid):
            try:
                for j in range(5):
                    ro = q_orderkey_ro() if (cid + j) % 2 else q_partkey_ro()
                    key = "ok" if (cid + j) % 2 else "pk"
                    res = front.run(ro, coalesce=False, timeout=120,
                                    block=True)
                    _assert_same(aggregate_result(res.values, ro), want[key])
            except BaseException as e:  # noqa: BLE001
                errors.append((cid, e))

        clients = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=300)
        assert not errors, f"thrash failures: {errors[:3]}"
        st = sess.plan_cache_stats()
        assert st["size"] <= 2
        # counters stay monotone and sane across concurrent eviction
        assert st["hits"] >= 0 and st["misses"] >= 1
        assert dr.plan_cache_stats()["plans"] <= 2
        front.close()
    finally:
        dr.set_plan_cache_capacity(old_cap)


# ---------------------------------------------------------------------------
# tenancy: budgets and fault isolation
# ---------------------------------------------------------------------------

def _tenant_tables():
    rng = np.random.default_rng(7)
    return {"k": rng.integers(0, 40, 3000),
            "v": rng.integers(0, 100, 3000).astype(np.float64)}


def _tenant_query(tenant):
    wl = tenant.workload()
    x = wl.scan("t")
    wl.aggregate(x, key=x["k"], reducer="sum")
    return wl


def test_tenant_budget_exhaustion_is_isolated():
    sess = Session(num_workers=4)
    front = sess.serve(max_workers=4, max_queue=32)
    data = _tenant_tables()
    alice = front.tenant("alice", memory_budget_bytes=1 << 16)
    bob = front.tenant("bob")
    alice.write("t", data)
    bob.write("t", data)
    want = aggregate_result(bob.run(_tenant_query(bob), timeout=120).values,
                            _tenant_query(bob))

    with pytest.raises(TenantBudgetError):
        alice.write("big", {"x": np.zeros(1 << 16)})
    # the rejected write left no trace in the shared store
    assert not any(n.endswith("big") for n in sess.store.datasets)
    # ...and bob's traffic is entirely unaffected
    got = aggregate_result(bob.run(_tenant_query(bob), timeout=120).values,
                           _tenant_query(bob))
    _assert_same(got, want)
    # alice can still serve reads within budget
    alice.run(_tenant_query(alice), timeout=120)
    front.close()


def test_tenant_bad_udf_fails_only_its_ticket():
    sess = Session(num_workers=4)
    front = sess.serve(max_workers=4, max_queue=32)
    data = _tenant_tables()
    alice = front.tenant("alice")
    bob = front.tenant("bob")
    alice.write("t", data)
    bob.write("t", data)

    bad = alice.workload()
    x = bad.scan("t")
    bad.map(x, lambda c: {"z": c["no_such_column"]}, tag="bad")
    bad_t = alice.submit(bad)
    good_ts = [bob.submit(_tenant_query(bob), block=True) for _ in range(6)]

    with pytest.raises(KeyError):
        bad_t.result(120)
    for t in good_ts:
        t.result(120)                  # no cross-tenant fallout
    st = front.stats()
    assert st["failed"] == 1
    front.close()


def test_tenant_namespaces_are_disjoint_in_shared_store():
    sess = Session(num_workers=4)
    front = sess.serve()
    a, b = front.tenant("alice"), front.tenant("bob")
    a.write("t", {"k": np.arange(10), "v": np.ones(10)})
    b.write("t", {"k": np.arange(20), "v": np.ones(20)})
    assert a.read("t").num_rows == 10
    assert b.read("t").num_rows == 20
    assert {"alice::t", "bob::t"} <= set(sess.store.datasets)
    assert a.used_bytes() != b.used_bytes()
    front.close()


# ---------------------------------------------------------------------------
# property test: reader / writer / evictor interleavings.  Driven by
# hypothesis where the dev extra is installed; otherwise the same checker
# runs over a fixed set of adversarial scripts so the race coverage never
# silently disappears from an environment.
# ---------------------------------------------------------------------------

OPS = ("read", "repartition", "spill", "prefetch", "flush")

_FALLBACK_CASES = [
    ([["read", "read", "read"], ["repartition", "repartition"]], 11),
    ([["read", "spill", "read"], ["repartition", "prefetch"]], 22),
    ([["spill", "prefetch", "spill"], ["read", "read", "read"],
      ["flush", "repartition"]], 33),
    ([["prefetch", "read"], ["spill", "flush"], ["repartition", "read"]], 44),
    ([["read"], ["spill"], ["prefetch"]], 55),
]


def _canonical(ds):
    """Row multiset in a layout-independent total order: rows with equal
    keys still compare bit-for-bit because (k, v) pairs sort together."""
    flat = ds.gather()
    order = np.lexsort((flat["v"], flat["k"]))
    return {k: np.asarray(v)[order] for k, v in flat.items()}


def _check_interleaving(scripts, seed):
    """Any interleaving of reads, layout swaps, spills, prefetches and
    flushes over a durable, budget-bound store preserves row multisets
    bit-for-bit and raises nothing."""
    rng = np.random.default_rng(seed)
    data = {"k": rng.integers(0, 1000, 2000),
            "v": rng.integers(0, 100, 2000).astype(np.float64)}
    with tempfile.TemporaryDirectory() as root:
        store = PartitionStore(num_workers=4, root=root,
                               max_retired_generations=8,
                               memory_budget_bytes=data["k"].nbytes
                               + data["v"].nbytes)   # tight: evicts eagerly
        store.write("d", data)
        store.flush()
        wl = Workload("probe")
        x = wl.scan("d")
        wl.aggregate(x, key=x["k"], reducer="sum")
        cand = enumerate_candidates(wl.graph, "d")[0]
        baseline = _canonical(store.read("d"))

        barrier = threading.Barrier(len(scripts))
        errors = []

        def run_script(ops):
            try:
                barrier.wait(timeout=30)
                for op in ops:
                    if op == "read":
                        got = _canonical(store.read("d"))
                        for k in baseline:
                            np.testing.assert_array_equal(got[k],
                                                          baseline[k])
                    elif op == "repartition":
                        store.repartition(store.read("d"), cand, swap=True)
                    elif op == "spill":
                        store.spill("d")
                    elif op == "prefetch":
                        store.prefetch("d")
                    elif op == "flush":
                        store.flush()
            except BaseException as e:  # noqa: BLE001
                errors.append((ops, e))

        threads = [threading.Thread(target=run_script, args=(ops,))
                   for ops in scripts]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, f"interleaving failed: {errors[:2]}"
        final = _canonical(store.read("d"))
        for k in baseline:
            np.testing.assert_array_equal(final[k], baseline[k])


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(st.lists(st.lists(st.sampled_from(OPS), min_size=1, max_size=4),
                    min_size=2, max_size=3),
           st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_reader_writer_evictor_interleavings(scripts, seed):
        _check_interleaving(scripts, seed)

except ImportError:                     # dev extra absent: fixed scripts
    @pytest.mark.parametrize("scripts,seed", _FALLBACK_CASES)
    def test_reader_writer_evictor_interleavings(scripts, seed):
        _check_interleaving(scripts, seed)
