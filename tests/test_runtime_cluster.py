"""Unit tests for the runtime modules the cluster tier wires up
(DESIGN §14): mesh replanning under node loss/gain
(:mod:`repro.runtime.elastic`), deterministic p50-window straggler
detection (:mod:`repro.runtime.straggler`), and the ClusterHealth
control plane built over the Coordinator heartbeats
(:mod:`repro.runtime.fault_tolerance`).  Everything runs with logical
clocks and injected latencies — no sleeps, no real nodes.
"""

import numpy as np
import pytest

from repro.cluster.control import (STRAGGLER_SIGNAL_DETECTIONS,
                                   ClusterHealth)
from repro.runtime.elastic import MeshPlan, replan_mesh, resharding_plan
from repro.runtime.fault_tolerance import Coordinator, RunState
from repro.runtime.straggler import StragglerConfig, StragglerMitigator


# ---------------------------------------------------------------------------
# elastic: mesh replanning
# ---------------------------------------------------------------------------

def test_replan_shrinks_data_axis_to_power_of_two():
    cur = MeshPlan((8, 2), ("data", "model"))
    assert cur.num_devices == 16
    new = replan_mesh(cur, 12)           # 4 devices lost
    assert new.shape == (4, 2)           # data 8 → 4 (largest pow2 ≤ 6)
    assert new.axes == ("data", "model")


def test_replan_grows_back_along_same_path():
    cur = MeshPlan((2, 2), ("data", "model"))
    assert replan_mesh(cur, 16).shape == (8, 2)


def test_replan_exact_fit_and_single_device():
    assert replan_mesh(MeshPlan((4, 1), ("data", "model")), 4).shape == (4, 1)
    assert replan_mesh(MeshPlan((4, 1), ("data", "model")), 1).shape == (1, 1)


def test_replan_fewer_devices_than_model_axis_raises():
    cur = MeshPlan((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="fewer surviving devices"):
        replan_mesh(cur, 3)              # model axis needs 4


def test_replan_collapses_degraded_pod_axis():
    cur = MeshPlan((2, 4, 2), ("pod", "data", "model"))
    new = replan_mesh(cur, 8)
    assert new.shape == (1, 4, 2)        # pod collapses into data
    assert new.axes == ("pod", "data", "model")


def test_resharding_plan_covers_every_row_once():
    old = MeshPlan((4, 1), ("data", "model"))
    new = replan_mesh(old, 2)            # data 4 → 2
    plan = resharding_plan(old, new, batch_dim=64)
    assert plan["per_device_batch"] == 32
    rows = []
    for a in plan["assignments"]:
        lo, hi = a["rows"]
        rows.extend(range(lo, hi))
        # each new shard reads only old shards that actually held its rows
        assert a["reads_old_shards"] == sorted(
            {r // (64 // 4) for r in range(lo, hi)})
    assert rows == list(range(64))


# ---------------------------------------------------------------------------
# straggler: deterministic p50-window detection
# ---------------------------------------------------------------------------

def test_threshold_needs_min_samples():
    mit = StragglerMitigator(StragglerConfig(min_samples=4))
    for _ in range(3):
        mit.record(0.01)
    assert mit.threshold() is None
    mit.record(0.01)
    assert mit.threshold() == pytest.approx(0.02)      # factor 2 × p50


def test_fetch_shard_reissues_on_injected_latency():
    mit = StragglerMitigator(StragglerConfig(min_samples=4, factor=2.0))
    calls = []

    def fetch(step, host):
        calls.append((step, host))
        return {"host": host}

    for step in range(4):                # establish the p50 ≈ 0.01 window
        mit.fetch_shard(fetch, step, host=0, backup_host=1,
                        simulated_latency=0.01)
    assert mit.reissues == 0
    shard = mit.fetch_shard(fetch, 4, host=0, backup_host=1,
                            simulated_latency=1.0)
    assert shard == {"host": 0}          # deterministic duplicate
    assert mit.reissues == 1
    assert calls.count((4, 0)) == 2      # reissued the same (step, host)
    assert mit.detections[-1] == (4, 0, 1.0)


def test_window_slides_so_old_slowness_ages_out():
    mit = StragglerMitigator(StragglerConfig(window=8, min_samples=4))
    for _ in range(8):
        mit.record(1.0)                  # a slow era
    assert mit.threshold() == pytest.approx(2.0)
    for _ in range(8):
        mit.record(0.01)                 # fast era displaces the window
    assert mit.threshold() == pytest.approx(0.02)


# ---------------------------------------------------------------------------
# ClusterHealth: heartbeats → node_lost, reads → straggler signals
# ---------------------------------------------------------------------------

def test_health_declares_silent_node_lost_once():
    h = ClusterHealth(("a", "b"), miss_threshold=3)
    sigs = []
    for step in range(1, 6):
        h.heartbeat("a", step)
        sigs += h.tick(step)
    assert [s.kind for s in sigs] == ["node_lost"]
    assert sigs[0].node == "b" and sigs[0].step == 3
    assert h.alive_nodes() == ["a"] and h.dead_nodes() == ["b"]
    assert h.heartbeat_misses >= 3
    # dedupe: the same (kind, node) never signals twice
    assert h.tick(6) == [] and h.signals() == [sigs[0]]
    # membership reset (post-rebalance) starts a fresh epoch of health
    h.reset_nodes(("a",))
    for step in range(1, 5):
        h.heartbeat("a", step)
        assert h.tick(step) == []
    assert h.dead_nodes() == []


def test_health_heartbeat_keeps_node_alive():
    h = ClusterHealth(("a", "b"), miss_threshold=2)
    for step in range(1, 10):
        h.heartbeat("a", step)
        h.heartbeat("b", step)
        assert h.tick(step) == []
    assert h.dead_nodes() == []
    h.heartbeat("nonexistent", 99)       # unknown nodes are ignored


def test_health_straggler_signal_after_repeated_detections():
    cfg = StragglerConfig(min_samples=4, factor=2.0)
    h = ClusterHealth(("a", "b", "c"), straggler=cfg)
    for _ in range(4):                   # fast baseline fills the window
        for n in ("a", "b", "c"):
            assert h.record_read(n, 0.01) is False
    sigs = []
    for i in range(STRAGGLER_SIGNAL_DETECTIONS):
        assert h.record_read("b", 1.0) is True     # cue to hit a replica
        sigs += h.signals()
    assert h.straggler_reissues == STRAGGLER_SIGNAL_DETECTIONS
    assert [s.kind for s in sigs] == ["straggler"]
    assert sigs[0].node == "b"
    assert sigs[0].detail["latency_s"] == pytest.approx(1.0)
    assert sigs[0].detail["detections"] == STRAGGLER_SIGNAL_DETECTIONS
    assert h.straggler_excess_s("b") > 0.3   # mean of b's window − p50
    assert h.straggler_excess_s("a") == pytest.approx(0.0, abs=1e-6)


def test_health_latency_injector_overrides_measured():
    h = ClusterHealth(("a",))
    h.set_read_latency(lambda node: 0.25)
    assert h.observed_latency("a", 99.0) == 0.25
    h.set_read_latency(lambda node: None)      # injector declines
    assert h.observed_latency("a", 0.5) == 0.5
    h.set_read_latency(None)
    assert h.observed_latency("a", 0.75) == 0.75


def test_coordinator_backoff_and_state_machine():
    c = Coordinator(2, miss_threshold=1, max_restarts=1)
    assert c.state == RunState.RUNNING
    ev = c.tick(1, checkpoint_step=0)
    assert ev is not None and c.state == RunState.RECOVERING
    assert c.backoff_s() == pytest.approx(0.1)
    c.recover()
    assert c.state == RunState.RUNNING
    ev2 = c.tick(2, checkpoint_step=1)   # second failure exceeds budget
    assert ev2 is not None and ev2.restart_step == 1
    assert c.state == RunState.FAILED
    assert c.backoff_s() == pytest.approx(0.2)
