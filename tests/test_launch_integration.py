"""Integration: one real dry-run cell in a 512-device subprocess, plus the
train/serve drivers end-to-end on CPU."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The 512-device flag must stay subprocess-local (tests see 1 device)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "internlm2-1.8b", "--shape", "decode_32k"],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "bottleneck" in out.stdout


def test_train_driver_reduced(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "internlm2-1.8b", "--reduced", "--steps", "8", "--batch", "2",
         "--seq", "64", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: loss" in out.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_serve_driver_reduced():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "mamba2-370m", "--reduced", "--batch", "2", "--prompt-len", "32",
         "--gen", "8"],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
