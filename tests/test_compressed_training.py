"""Gradient compression integrated into the train step: convergence + wire
bytes (the distributed-optimization trick wired end to end)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduced import reduced
from repro.launch import steps as S


def _setup(compression):
    cfg = reduced(get_config("internlm2-1.8b"))
    opt = S.make_optimizer(cfg, peak_lr=5e-3, total_steps=40)
    step = jax.jit(S.make_train_step(cfg, opt, compression=compression))
    key = jax.random.PRNGKey(0)
    state = S.init_train_state(cfg, key, opt, compression=compression)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    return cfg, step, state, batch


@pytest.mark.parametrize("compression", ["int8", "topk"])
def test_compressed_training_converges(compression):
    cfg, step, state, batch = _setup(compression)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"{compression} diverged: {losses}"
    # wire bytes beat the fp32 gradient payload
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state["params"]))
    assert float(m["wire_bytes"]) < n_params * 4


def test_compression_matches_uncompressed_early():
    """With error feedback, the first int8 step tracks the exact step."""
    cfg, step_c, state_c, batch = _setup("int8")
    _, step_u, state_u, _ = _setup(None)
    state_c, mc = step_c(state_c, batch)
    state_u, mu = step_u(state_u, batch)
    # same loss (forward identical); parameter delta within quantization err
    assert abs(float(mc["loss"]) - float(mu["loss"])) < 1e-5
    dc = jax.tree.leaves(state_c["params"])[0]
    du = jax.tree.leaves(state_u["params"])[0]
    rel = float(jnp.abs(dc - du).max())
    assert rel < 0.15
