"""Unit + property tests for the Lachesis IR and partitioner extraction."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import (HASH, IRGraph, RANGE, Workload, author_integrator,
                        dedupe, enumerate_candidates, keyless_candidates,
                        matmul_workload, merge, pagerank_iteration, search)


def test_ir_two_terminal_and_signature():
    wl = author_integrator()
    g = wl.graph
    assert len(g.scans) == 2 and len(g.writes) == 1
    assert len(g.partition_nodes) == 2
    sig1 = g.graph_signature()
    assert sig1 == author_integrator().graph.graph_signature()
    assert sig1 != pagerank_iteration().graph.graph_signature()


def test_alg1_alg2_extraction():
    wl = author_integrator()
    cands = enumerate_candidates(wl.graph, "submissions")
    assert len(cands) == 1
    c = cands[0]
    assert c.graph.is_two_terminal()
    assert c.strategy == HASH
    assert c.signature() == "scan/parse:json/attr:author/partition[hash]"
    # Listing-2 executability: recompiled key projection
    keys = c.key_fn()({"author": np.array([5, 3, 5])})
    assert list(np.asarray(keys)) == [5, 3, 5]


def test_extraction_matmul_and_pagerank():
    m = matmul_workload()
    lhs = enumerate_candidates(m.graph, "lhs_blocks")
    rhs = enumerate_candidates(m.graph, "rhs_blocks")
    assert len(lhs) == 1 and len(rhs) == 1
    assert "attr:col_id" in lhs[0].signature()
    assert "attr:row_id" in rhs[0].signature()

    pr = pagerank_iteration()
    pages = enumerate_candidates(pr.graph, "pages")
    assert len(pages) == 1 and "attr:url" in pages[0].signature()


def test_diamond_paths_merge_to_one_candidate():
    """Two scan→partition paths sharing terminals merge (Alg. 2)."""
    wl = Workload("diamond")
    ds = wl.scan("d")
    a = ds["x"]
    b = ds["y"]
    key = a + b                       # diamond: scan→x→+, scan→y→+
    wl.partition(key)
    paths = search(wl.graph, wl.graph.find_scanner("d"))
    assert len(paths) == 2
    cands = merge(wl.graph, paths)
    assert len(cands) == 1
    assert cands[0].graph.is_two_terminal()
    # executable: (x + y)
    out = cands[0].key_fn()({"x": np.array([1, 2]), "y": np.array([10, 20])})
    assert list(np.asarray(out)) == [11, 22]


def test_complexity_and_keyless():
    c = enumerate_candidates(author_integrator().graph, "submissions")[0]
    assert c.complexity() > 0
    for kc in keyless_candidates():
        assert not kc.is_keyed
        ids = kc.partition_ids({"x": np.arange(10)}, 4)
        assert ids.shape == (10,) and int(ids.max()) < 4


def test_range_vs_hash_distinct_signatures():
    wl1 = Workload("w1")
    d1 = wl1.scan("d")
    wl1.partition(d1["k"], strategy=HASH)
    wl2 = Workload("w2")
    d2 = wl2.scan("d")
    wl2.partition(d2["k"], strategy=RANGE)
    c1 = enumerate_candidates(wl1.graph, "d")[0]
    c2 = enumerate_candidates(wl2.graph, "d")[0]
    assert c1.signature() != c2.signature()


# -- property tests -----------------------------------------------------------

@st.composite
def random_key_chain(draw):
    """A random unary chain scan→…→partition plus distractor branches."""
    wl = Workload("rand")
    ds = wl.scan("d")
    col = ds
    ops = draw(st.lists(st.sampled_from(["a", "b", "c"]), min_size=0,
                        max_size=4))
    for name in ops:
        col = col[name]
    wl.partition(col)
    # distractor: a second consumer that writes without partitioning
    wl.write(wl.map(ds, fn=None, tag="noop"), "out")
    return wl, ops


@given(random_key_chain())
@settings(max_examples=30, deadline=None)
def test_property_candidates_two_terminal(wl_ops):
    wl, ops = wl_ops
    cands = enumerate_candidates(wl.graph, "d")
    assert len(cands) == 1
    c = cands[0]
    assert c.graph.is_two_terminal()
    # signature mirrors the chain
    assert c.signature().count("attr:") == len(ops)


@given(st.integers(1, 64), st.integers(2, 16),
       st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_property_hash_partition_ids_in_range(seed, m, keys):
    from repro.core.partitioner import PartitionerCandidate
    wl = Workload("w")
    ds = wl.scan("d")
    wl.partition(ds["k"])
    c = enumerate_candidates(wl.graph, "d")[0]
    ids = np.asarray(c.partition_ids({"k": np.array(keys, np.int64)}, m))
    assert ids.min() >= 0 and ids.max() < m
    # determinism
    ids2 = np.asarray(c.partition_ids({"k": np.array(keys, np.int64)}, m))
    assert np.array_equal(ids, ids2)
