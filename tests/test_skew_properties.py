"""Hypothesis property sweeps for skew-adaptive layouts (DESIGN §12).

Split/merge (bucketed) layouts must be bit-for-bit identical to the
uniform padded layout for *any* keys — every payload dtype the workloads
use, arbitrary skew (small key domains collapse most rows into one
partition), zero-row partitions (zero-capacity buckets), and the d2d vs
host write routes.  Needs the hypothesis dev extra; self-skips without it.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import repro.data.device_repartition as dr
from repro.core import author_integrator, enumerate_candidates
from repro.data.capacity import CapacityMap, valid_slot_index
from repro.data.partition_store import PartitionStore
from repro.data.skew import zipf_keys

PAYLOAD_DTYPES = (np.float32, np.int32, np.float64, np.int64)


@given(st.integers(2, 16),
       st.integers(0, len(PAYLOAD_DTYPES) - 1),
       st.integers(0, 3),                      # key domain exponent → skew
       st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=300))
@settings(max_examples=20, deadline=None)
def test_bucketed_scatter_rows_equal_uniform(m, pdt, dom, raw):
    keys = np.array(raw, np.int64) % (4 ** dom + 1)
    n = keys.shape[0]
    data = {"k": keys,
            "v": (np.arange(n) * 3).astype(PAYLOAD_DTYPES[pdt]),
            "mat": np.arange(2 * n, dtype=np.float32).reshape(n, 2)}
    pids_d, hist = dr.device_partition_ids(keys, m)
    counts = np.asarray(hist).astype(np.int64)
    cmap = CapacityMap.from_counts(counts)     # force bucketing, including
                                               # zero-capacity partitions
    uni = dr.device_scatter_padded(data, pids_d, counts)
    buck = dr.device_scatter_padded(data, pids_d, counts, capacity_map=cmap)
    cap = int(counts.max())
    uni_off = np.arange(m, dtype=np.int64) * cap
    vidx_u = valid_slot_index(counts, uni_off)
    vidx_b = valid_slot_index(counts, cmap.offsets)
    for k, v in data.items():
        got_u = np.asarray(uni[k]).reshape((m * cap,) + v.shape[1:])[vidx_u]
        got_b = np.asarray(buck[k])[vidx_b]
        assert got_b.dtype == v.dtype, k
        np.testing.assert_array_equal(got_u, got_b, err_msg=k)


@given(st.integers(2, 8), st.floats(1.05, 2.5),
       st.integers(40, 300), st.booleans())
@settings(max_examples=15, deadline=None)
def test_adaptive_store_gather_equals_uniform_store(m, alpha, n, device):
    """The same keyed write through an adaptive store (capacity map
    allowed) and a plain store (always uniform) gathers back identical
    flat rows — host path and d2d path both, 64-bit hybrid included."""
    keys = zipf_keys(n, n, alpha, seed=7)
    cols = {"author": keys,
            "v64": np.arange(n, dtype=np.int64),     # hybrid 64-bit path
            "v32": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    backend = "device" if device else "host"
    out = {}
    for adaptive in (False, True):
        store = PartitionStore(m, backend=backend,
                               adaptive_capacity=adaptive)
        ds = store.write("submissions", cols, cand)
        out[adaptive] = (ds, ds.gather())
    ds_u, flat_u = out[False]
    ds_a, flat_a = out[True]
    assert ds_u.capacity_map is None
    np.testing.assert_array_equal(ds_u.counts, ds_a.counts)
    for k in flat_u:
        assert flat_a[k].dtype == flat_u[k].dtype, k
        np.testing.assert_array_equal(np.asarray(flat_u[k]),
                                      np.asarray(flat_a[k]), err_msg=k)
