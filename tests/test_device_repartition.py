"""Device-resident repartition path (DESIGN §5).

Engine ``backend="device"`` must execute the TPC-H, Reddit, and PageRank
example workloads through the Pallas hash-partition kernel (interpret mode
on CPU) **bit-identically** to the host numpy path — same values, dtypes,
and per-worker counts at every set-valued node.  No hypothesis dependency:
these run even in the bare container.
"""

import numpy as np
import pytest

from repro.core import (Engine, Workload, author_integrator,
                        enumerate_candidates, pagerank_iteration)
from repro.core.engine import TableVal
from repro.data.device_repartition import (as_kernel_keys, device_rebucket,
                                           device_scatter_padded,
                                           device_partition_ids)
from repro.data.partition_store import PartitionStore


# -- workload builders (mirror the benchmark data, CPU-sized) -----------------

def _reddit_case(n_sub=4000, n_auth=800, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32),
            "ups": rng.integers(0, 1000, n_sub).astype(np.int32)}
    auths = {"author": np.arange(n_auth, dtype=np.int64),
             "karma": rng.normal(size=n_auth).astype(np.float32)}
    return author_integrator(), {"submissions": subs, "authors": auths}


def _pagerank_case(n=1500, fanout=4, seed=1):
    rng = np.random.default_rng(seed)
    pages = {"url": np.arange(n, dtype=np.int64),
             "neighbors": rng.integers(0, n, (n, fanout)).astype(np.int64)}
    ranks = {"url": np.arange(n, dtype=np.int64),
             "rank": np.full(n, 1.0 / n, np.float64)}
    wl = pagerank_iteration()

    def emit(cols):
        contrib = np.repeat((cols["rank"] / fanout)[:, None], fanout, 1)
        return {"url": cols["neighbors"], "contrib": contrib}
    for node in wl.graph.nodes.values():
        if node.params.get("tag") == "emit_contribs":
            node.params["fn"] = emit
    return wl, {"pages": pages, "ranks": ranks}


def _tpch_case(seed=2):
    rng = np.random.default_rng(seed)
    n_orders, n_lines = 3000, 12_000
    orders = {"orderkey": np.arange(n_orders, dtype=np.int64),
              "odate": rng.integers(0, 2556, n_orders).astype(np.int32)}
    lineitem = {"orderkey": rng.integers(0, n_orders, n_lines),
                "qty": rng.integers(1, 50, n_lines).astype(np.float32)}
    wl = Workload("q04-like")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    agg = wl.aggregate(j, key=j["odate"], reducer="sum")
    wl.write(agg, "q04_out")
    return wl, {"lineitem": lineitem, "orders": orders}


CASES = {"reddit": _reddit_case, "pagerank": _pagerank_case,
         "tpch": _tpch_case}


def _run(wl, tables, backend, workers=8):
    store = PartitionStore(workers)
    for name, data in tables.items():
        store.write(name, data)           # rr ⇒ every repartition is real
    eng = Engine(store, backend=backend)
    return eng.run(wl)


@pytest.mark.parametrize("case", sorted(CASES))
def test_device_backend_bit_identical(case):
    wl, tables = CASES[case]()
    vals_h, stats_h = _run(wl, tables, "host")
    wl2, tables2 = CASES[case]()
    vals_d, stats_d = _run(wl2, tables2, "device")

    assert stats_d.device_repartitions == stats_d.shuffles_performed > 0
    assert stats_h.device_repartitions == 0
    assert stats_h.shuffles_performed == stats_d.shuffles_performed
    assert stats_h.shuffle_bytes == stats_d.shuffle_bytes

    for nid, h in vals_h.items():
        if not isinstance(h, TableVal):
            continue
        d = vals_d[nid]
        np.testing.assert_array_equal(h.counts, d.counts)
        assert set(h.columns) == set(d.columns)
        for k in h.columns:
            assert h.columns[k].dtype == d.columns[k].dtype, (nid, k)
            np.testing.assert_array_equal(h.columns[k], d.columns[k],
                                          err_msg=f"node {nid} col {k}")


def test_store_roundtrip_device_repartition():
    """Round-trip a stored dataset through device repartition and compare
    exactly against the host numpy path (ISSUE satellite)."""
    wl, tables = _reddit_case()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    data = tables["submissions"]

    host = PartitionStore(8)
    dev = PartitionStore(8, backend="device")
    ds_h = host.write("submissions", data)            # round-robin first
    ds_d = dev.write("submissions", data)
    new_h, moved_h = host.repartition(ds_h, cand)
    new_d, moved_d = dev.repartition(ds_d, cand)

    assert new_d.backend == "device" and new_h.backend == "host"
    assert moved_h == moved_d
    np.testing.assert_array_equal(new_h.counts, new_d.counts)
    flat_h, flat_d = new_h.gather(), new_d.gather()
    for k in flat_h:
        assert flat_h[k].dtype == flat_d[k].dtype
        np.testing.assert_array_equal(flat_h[k], flat_d[k])
    # to_host flattens the residency split but keeps the layout
    back = new_d.to_host()
    assert back.backend == "host"
    np.testing.assert_array_equal(np.asarray(new_d.columns["score"]),
                                  back.columns["score"])


def test_device_rebucket_matches_host_rebucket():
    rng = np.random.default_rng(7)
    n, m = 3001, 13
    cols = {"k": rng.integers(0, 10_000, n).astype(np.int64),
            "v32": rng.normal(size=n).astype(np.float32),
            "v64": rng.normal(size=n),                  # stays host-side
            "mat": rng.normal(size=(n, 3)).astype(np.float32)}
    keys = cols["k"]

    from repro.core.ir import _mix_hash
    pids = np.asarray(_mix_hash(keys)).astype(np.int64) % m
    order = np.argsort(pids, kind="stable")
    want_counts = np.bincount(pids, minlength=m).astype(np.int64)

    got, counts = device_rebucket(cols, keys, m)
    np.testing.assert_array_equal(counts, want_counts)
    for k, v in cols.items():
        assert got[k].dtype == v.dtype
        np.testing.assert_array_equal(got[k], v[order])
    np.testing.assert_array_equal(got["__key__"], keys[order])


def test_device_write_layout_matches_host():
    rng = np.random.default_rng(5)
    counts = np.array([3, 0, 5, 2], np.int64)
    n = int(counts.sum())
    flat = {"a": rng.normal(size=n).astype(np.float32),
            "b": rng.integers(0, 9, n).astype(np.int64)}
    ds_h = PartitionStore(4).write_layout("d", flat, counts, None)
    ds_d = PartitionStore(4, backend="device").write_layout(
        "d", flat, counts, None)
    np.testing.assert_array_equal(ds_h.counts, ds_d.counts)
    for k in ds_h.columns:
        np.testing.assert_array_equal(ds_h.columns[k],
                                      np.asarray(ds_d.columns[k]))


def test_device_write_empty_dataset():
    """0-row hash writes must not crash the kernel path (zero-size grid)."""
    wl, _ = _reddit_case()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    empty = {"author": np.zeros(0, np.int64),
             "score": np.zeros(0, np.float32)}
    ds_h = PartitionStore(8).write("submissions", empty, cand)
    ds_d = PartitionStore(8, backend="device").write("submissions", empty,
                                                    cand)
    np.testing.assert_array_equal(ds_h.counts, ds_d.counts)
    assert ds_d.num_rows == 0 and ds_d.capacity == ds_h.capacity


def test_device_put_dataset_places_worker_axis():
    """sharding_bridge.device_put_dataset commits round-trippable columns to
    the mesh with the worker axis sharded; 64-bit columns stay host-side."""
    import jax
    from jax.sharding import Mesh
    from repro.core.sharding_bridge import device_put_dataset, sharding_for

    wl, tables = _reddit_case(n_sub=500, n_auth=100)
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    ds = PartitionStore(8, backend="device").write(
        "submissions", tables["submissions"], cand)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    placed = device_put_dataset(mesh, ds)

    assert isinstance(placed.columns["score"], jax.Array)
    assert placed.columns["score"].sharding == sharding_for(mesh,
                                                            ds.partitioner)
    assert isinstance(placed.columns["author"], np.ndarray)  # int64, x64 off
    np.testing.assert_array_equal(np.asarray(placed.columns["score"]),
                                  np.asarray(ds.columns["score"]))
    # divisibility check fires before any placement, so a stub mesh works
    class TwoWideMesh:
        shape = {"data": 2}
    bad = PartitionStore(3).write("s", tables["authors"])   # m=3, extent=2
    with pytest.raises(ValueError, match="not divisible"):
        device_put_dataset(TwoWideMesh(), bad)


def test_device_rebucket_empty():
    got, counts = device_rebucket({"v": np.zeros(0, np.float32)},
                                  np.zeros(0, np.int64), 4)
    assert counts.tolist() == [0, 0, 0, 0]
    assert got["v"].size == 0 and "__key__" in got


def test_scatter_padded_matches_host_layout():
    rng = np.random.default_rng(11)
    n, m = 700, 6
    data = {"k": rng.integers(0, 50, n).astype(np.int64),
            "v": rng.normal(size=n).astype(np.float32)}
    pids, hist = device_partition_ids(data["k"], m)
    counts = np.asarray(hist).astype(np.int64)
    cols = device_scatter_padded(data, pids, counts)

    # reference: the host store write loop
    pids_np = np.asarray(pids).astype(np.int64)
    order = np.argsort(pids_np, kind="stable")
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    cap = int(counts.max())
    for k, v in data.items():
        buf = np.zeros((m, cap) + v.shape[1:], v.dtype)
        sv = v[order]
        for w in range(m):
            c = counts[w]
            if c:
                buf[w, :c] = sv[offsets[w]:offsets[w] + c]
        got = np.asarray(cols[k])
        assert got.dtype == v.dtype
        np.testing.assert_array_equal(got, buf)


def test_kernel_key_normalization_matches_mix_hash():
    """as_kernel_keys must reproduce _mix_hash's dtype canonicalization for
    every key dtype the workloads use."""
    import jax.numpy as jnp
    from repro.core.ir import _mix_hash
    rng = np.random.default_rng(13)
    cases = [rng.integers(0, 2 ** 31 - 1, 257).astype(np.int64),
             rng.integers(0, 1000, 257).astype(np.int32),
             rng.normal(size=257).astype(np.float32),
             rng.normal(size=257),                       # float64
             rng.integers(0, 2, 257).astype(bool)]
    for keys in cases:
        pids, _ = device_partition_ids(keys, 16)
        want = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % 16
        np.testing.assert_array_equal(np.asarray(pids), want,
                                      err_msg=str(keys.dtype))
