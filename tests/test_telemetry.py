"""Cluster-wide observability tests (DESIGN §15): cross-process trace
stitching, the durable telemetry history, cluster metrics aggregation,
and the regression watchdog's signal path into the Autopilot.

The cross-process pieces are exercised in-process with separate
:class:`Tracer` instances standing in for separate interpreters — the
real three-interpreter path runs in ``scripts/cluster_smoke.py`` (wired
into verify.sh and CI), which machine-checks the same invariants on the
stitched artifact.
"""

import gc
import json
import math
import os

import pytest

from repro import obs
from repro.api import Session
from repro.cluster import ClusterConfig, RebalanceAborted
from repro.core import Workload
from repro.data.partition_store import PartitionStore
from repro.obs.export import (load_spill, merge_process_traces, spill_spans)
from repro.obs.metrics import (MetricsRegistry, merge_node_snapshots,
                               parse_prometheus_text,
                               snapshot_prometheus_text)
from repro.obs.telemetry import (RunProfile, TELEMETRY_SCHEMA_VERSION,
                                 TelemetryStore)
from repro.obs.tracer import TRACE_ENV_VAR, TraceContext, Tracer
from repro.obs.watchdog import RegressionDetector
from repro.service import AutopilotConfig, LogicalClock, drift_tables

from test_observability import _seed_session, _tracer_reset  # noqa: F401


def _query(scan="lineitem", key="orderkey") -> Workload:
    wl = Workload("telemetry-q")
    t = wl.scan(scan)
    p = wl.partition(t[key])
    wl.aggregate(p, reducer="sum")
    return wl


# ---------------------------------------------------------------------------
# TraceContext wire format
# ---------------------------------------------------------------------------

def test_trace_context_wire_roundtrip_and_env_carrier(monkeypatch):
    ctx = TraceContext(trace_id=7, span_id=42, tid=5, thread_name="main",
                       captured_at=123.0, process="alpha",
                       captured_unix=1.7e9)
    wire = ctx.to_wire()
    back = TraceContext.from_wire(wire)
    assert (back.trace_id, back.span_id, back.process) == (7, 42, "alpha")
    assert back.captured_unix == pytest.approx(1.7e9)
    # perf_counter stamps are process-local: they never cross the wire
    assert back.captured_at == 0.0 and "captured_at" not in wire

    # env carrier: what one process exports, a child process parses
    monkeypatch.setenv(TRACE_ENV_VAR, ctx.to_env()[TRACE_ENV_VAR])
    got = TraceContext.from_env()
    assert got is not None and got.span_id == 42 and got.process == "alpha"

    monkeypatch.setenv(TRACE_ENV_VAR, "{not json")
    assert TraceContext.from_env() is None
    monkeypatch.delenv(TRACE_ENV_VAR)
    assert TraceContext.from_env() is None

    # a record from an older build (missing new fields) still loads...
    old = {"v": 1, "trace_id": 1, "span_id": 2, "tid": 0,
           "thread_name": "t"}
    assert TraceContext.from_wire(old).process == ""
    # ...a record from a future build refuses loudly
    with pytest.raises(ValueError, match="version"):
        TraceContext.from_wire(dict(wire, v=99))


# ---------------------------------------------------------------------------
# TelemetryStore: durable, bounded, tolerant
# ---------------------------------------------------------------------------

def test_run_profile_record_roundtrip_tolerates_unknown_fields():
    p = RunProfile(t=1.0, workload="w", wall_s=2.5, plan_cache_hit=True,
                   placement_epoch=3, generations={"events": 2})
    rec = p.to_record()
    rec["from_the_future"] = "ignored"
    back = RunProfile.from_record(rec)
    assert back == p


def test_telemetry_store_appends_reads_and_tolerates_garbage(tmp_path):
    tele = TelemetryStore(str(tmp_path))
    tele.record_run(RunProfile(t=1.0, workload="a", wall_s=0.5))
    tele.record_tick({"tick": 1, "considered": 0})
    tele.record_run(RunProfile(t=2.0, workload="b", wall_s=0.7))
    with open(tele.path, "a") as f:
        f.write(json.dumps({"v": TELEMETRY_SCHEMA_VERSION + 1,
                            "kind": "run", "workload": "future"}) + "\n")
        f.write('{"torn')                     # crash mid-append

    with pytest.warns(UserWarning, match="version"):
        profiles = tele.run_profiles()
    assert [p.workload for p in profiles] == ["a", "b"]
    assert len(tele.records(kind="tick")) == 1
    assert tele.run_profiles(limit=1)[0].workload == "b"
    # seq increases monotonically across kinds
    seqs = [r["seq"] for r in tele.records()
            if r.get("kind") in ("run", "tick")]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_telemetry_compaction_bounds_the_file(tmp_path):
    tele = TelemetryStore(str(tmp_path), max_records=10, compact_slack=5)
    for i in range(40):
        tele.record_run(RunProfile(t=float(i), workload=f"w{i}",
                                   wall_s=1.0, retraces=1,
                                   plan_cache_hit=(i % 2 == 0)))
    assert tele.compactions >= 1
    # bounded: at most max_records + slack live records + the summary
    with open(tele.path) as f:
        n_lines = sum(1 for _ in f)
    assert n_lines <= 10 + 5 + 1
    # nothing is lost: evicted runs folded into the aggregate
    summ = tele.summary()
    kept = tele.run_profiles()
    assert summ["runs"] + len(kept) == 40
    assert summ["wall_s_sum"] == pytest.approx(float(summ["runs"]))
    assert summ["retraces"] == summ["runs"]
    assert summ["first_t"] == 0.0
    # the newest records survive verbatim, oldest-first
    assert kept[-1].workload == "w39"
    # a fresh handle over the compacted file sees the same state
    tele2 = TelemetryStore(str(tmp_path), max_records=10)
    assert tele2.summary()["runs"] == summ["runs"]
    assert [p.workload for p in tele2.run_profiles()] == \
        [p.workload for p in kept]


def test_session_records_run_profiles_and_survives_restart(tmp_path):
    root = tmp_path / "s"
    sess = _seed_session(root, n=800)
    wl = _query()
    sess.run(wl)
    sess.run(wl)
    profiles = sess.telemetry()
    assert len(profiles) == 2
    cold, warm = profiles
    assert cold.workload == warm.workload == "telemetry-q"
    assert not cold.plan_cache_hit and warm.plan_cache_hit
    assert warm.retraces == 0                 # cache hit ⇒ no new traces
    assert warm.wall_s > 0 and warm.valid_bytes > 0
    assert "lineitem" in warm.generations     # the plan's generation pins

    # a FRESH session over the same root reads the history and appends
    sess2 = Session(PartitionStore(num_workers=4, backend="host",
                                   root=str(root)))
    assert len(sess2.telemetry()) == 2
    sess2.run(_query())
    assert len(sess2.telemetry()) == 3
    assert sess2.telemetry(limit=1)[0].plan_cache_hit is not None

    # memory-only stores have no telemetry and say so cheaply
    mem = Session(PartitionStore(num_workers=4, backend="host"))
    assert mem.telemetry() == [] and mem.telemetry_store is None


# ---------------------------------------------------------------------------
# regression watchdog
# ---------------------------------------------------------------------------

def _fill(tele, n, wall, t0=0.0, retraces=0, padded=100, valid=100):
    for i in range(n):
        tele.record_run(RunProfile(t=t0 + i, workload="w", wall_s=wall,
                                   retraces=retraces, padded_bytes=padded,
                                   valid_bytes=valid))


def test_watchdog_baseline_regression_dedupe_and_rearm(tmp_path):
    tele = TelemetryStore(str(tmp_path))
    wd = RegressionDetector(tele, window=8, tolerance=1.5, min_runs=4)
    # no baseline yet → check is a no-op
    _fill(tele, 8, wall=1.0)
    assert wd.check() == []
    base = wd.record_baseline()
    assert base["stats"]["run_wall_p50_s"] == pytest.approx(1.0)
    assert os.path.exists(wd.baseline_path)

    # within tolerance: quiet
    _fill(tele, 8, wall=1.2, t0=100)
    assert wd.check(step=1) == []

    # regression: exactly one signal per excursion, however many checks
    _fill(tele, 8, wall=2.0, t0=200)
    (sig,) = wd.check(step=2)
    assert sig.kind == "perf_regression" and sig.node == "run_wall_p50_s"
    assert sig.detail["ratio"] == pytest.approx(2.0)
    assert sig.detail["baseline"] == pytest.approx(1.0)
    assert wd.check(step=3) == []             # deduped while still bad
    assert [s.step for s in wd.signals()] == [2]
    assert wd.signals() == []                 # drain-once protocol

    # recovery re-arms the series: the next excursion signals again
    _fill(tele, 8, wall=1.0, t0=300)
    assert wd.check(step=4) == []
    _fill(tele, 8, wall=3.0, t0=400)
    (sig2,) = wd.check(step=5)
    assert sig2.detail["ratio"] == pytest.approx(3.0)
    assert wd.raised_total == 2

    # lower-is-worse series: a coalesce-rate COLLAPSE alerts
    reg = MetricsRegistry()
    c = reg.counter("serving_completed")
    k = reg.counter("serving_coalesced")
    c.inc(100), k.inc(80)
    wd2 = RegressionDetector(tele, window=8, tolerance=1.5, min_runs=4,
                             registry=reg)
    wd2.record_baseline()
    c.inc(900)                                # rate 80/1000 << 80/100
    names = {s.node for s in wd2.check()}
    assert "coalesce_rate" in names


def test_watchdog_alerts_become_autopilot_why_records(tmp_path):
    sess = _seed_session(tmp_path / "s", n=800)
    wl = _query()
    for _ in range(4):
        sess.run(wl)
    wd = sess.watchdog
    wd.min_runs = 4
    wd.record_baseline()
    # a sustained 10x wall regression, injected as telemetry history
    _fill(sess.telemetry_store, 32,
          wall=sess.telemetry()[0].wall_s * 10, t0=1e9)

    ap = sess.autopilot(clock=LogicalClock(), config=AutopilotConfig())
    rep = ap.tick()
    alerts = [w for w in rep.why
              if w["action"] == "watchdog:perf_regression"]
    assert alerts, "watchdog alert did not reach the tick's why-records"
    w = alerts[0]
    assert w["candidate"] == "run_wall_p50_s" and w["accepted"]
    (g,) = w["gates"]
    assert g["gate"] == "tolerance_exceeded" and g["passed"]
    assert g["ratio"] > g["tolerance"] > 1.0
    # the alert is explainable after the fact, like any other decision
    assert any(r["action"] == "watchdog:perf_regression"
               for r in sess.explain_decisions())
    # and the tick itself landed in the durable telemetry
    ticks = sess.telemetry_store.records(kind="tick")
    assert ticks and ticks[-1]["why_count"] == len(rep.why)


# ---------------------------------------------------------------------------
# cross-process trace stitching (two in-process "processes")
# ---------------------------------------------------------------------------

def test_spill_merge_stitches_two_processes(tmp_path):
    d = str(tmp_path / "tele")
    # "process" alpha: a finished root span whose context crosses the wire
    a = Tracer().configure(mode="full", process="alpha")
    with a.span("alpha.root", "smoke", phase=1):
        wire = a.context().to_wire()
    spill_spans(d, tracer=a)

    # "process" beta: attaches to alpha's wire context, then dies with a
    # span still open — spilled mid-flight, like a crash handler would
    b = Tracer().configure(mode="full", process="beta")
    with b.attach(TraceContext.from_wire(wire)):
        with b.span("beta.root", "smoke"):
            open_sp = b.span("beta.dies_inside", "smoke")
            open_sp.__enter__()
            spill_spans(d, tracer=b)

    loaded = load_spill(os.path.join(d, "trace-alpha.jsonl"))
    assert loaded["header"]["process"] == "alpha"
    assert loaded["header"]["mode"] == "full"

    doc = merge_process_traces(d)
    other = doc["otherData"]
    assert set(other["processes"]) == {"alpha", "beta"}
    pid_a, pid_b = (other["processes"][p] for p in ("alpha", "beta"))
    assert pid_a != pid_b

    events = doc["traceEvents"]
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert names == {"alpha", "beta"}

    xs = [e for e in events if e["ph"] == "X"]
    assert {e["pid"] for e in xs} == {pid_a, pid_b}
    # the open span survived, flagged, with a non-negative duration
    (dying,) = [e for e in xs if e["name"] == "beta.dies_inside"]
    assert dying["args"]["incomplete"] is True and dying["dur"] >= 0
    # beta.root was ALSO still on the stack at spill time
    (broot,) = [e for e in xs if e["name"] == "beta.root"]
    assert broot["args"]["incomplete"] is True
    assert other["incomplete"] == 2

    # process-qualified ids: beta's root parents onto ALPHA's span
    (aroot,) = [e for e in xs if e["name"] == "alpha.root"]
    assert aroot["args"]["span_uid"].startswith("alpha/")
    assert broot["args"]["parent_uid"] == aroot["args"]["span_uid"]

    # one cross-process flow arrow, s on alpha's pid, f on beta's
    (s,) = [e for e in events if e["ph"] == "s" and e["name"] == "xproc"]
    (fl,) = [e for e in events if e["ph"] == "f" and e["name"] == "xproc"]
    assert s["id"] == fl["id"]
    assert s["pid"] == pid_a and fl["pid"] == pid_b
    assert other["cross_process_flows"] == 1
    # merged timestamps are rebased: everything is near-zero, not 1e15
    assert all(0 <= e["ts"] < 60e6 for e in xs)

    open_sp.__exit__(None, None, None)


def test_spill_skips_future_version_files(tmp_path):
    d = str(tmp_path)
    a = Tracer().configure(mode="full", process="ok")
    with a.span("fine"):
        pass
    spill_spans(d, tracer=a)
    with open(os.path.join(d, "trace-future.jsonl"), "w") as f:
        f.write(json.dumps({"kind": "header", "version": 99,
                            "process": "future", "anchor_perf": 0,
                            "anchor_unix": 0}) + "\n")
    with pytest.warns(UserWarning, match="version"):
        doc = merge_process_traces(d)
    assert set(doc["otherData"]["processes"]) == {"ok"}
    assert doc["otherData"]["skipped_files"] == 1


def test_killed_rebalance_leaves_incomplete_span(tmp_path):
    """Satellite regression test: a rebalance killed mid-stream must
    leave an open ``cluster.rebalance`` span in the crash spill, and the
    merged trace must flag it ``incomplete``."""
    obs.enable("full", process="crash")
    root = str(tmp_path / "c")
    sess = Session(store_path=root, num_workers=4,
                   cluster=ClusterConfig(nodes=("n0", "n1"), replication=2))
    tele = sess.telemetry_store
    for name, data in drift_tables(n_lineitem=600, n_orders=200,
                                   n_parts=80).items():
        sess.write(name, data)
    plan = sess.plan_rebalance(add_nodes=("n2",), reason="test-kill")
    with pytest.raises(RebalanceAborted):
        sess.rebalance(plan=plan, abort_after=1,
                       on_abort=lambda: spill_spans(tele.dir))
    doc = merge_process_traces(tele.dir)
    reb = [e for e in doc["traceEvents"]
           if e["ph"] == "X" and e["name"] == "cluster.rebalance"]
    assert reb and reb[0]["args"]["incomplete"] is True
    assert reb[0]["args"]["process"] == "crash"
    # after the abort unwound, the live tracer's span DID close — only
    # the crash-point spill preserves the in-flight view
    live = [sp for sp in obs.finished_spans()
            if sp.name == "cluster.rebalance"]
    assert live and live[0].t1 is not None


# ---------------------------------------------------------------------------
# Prometheus text: strict round-trip + node-labeled cluster merge
# ---------------------------------------------------------------------------

def _every_kind_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    c = reg.counter("requests_total", help="reqs",
                    labels={"path": 'a\\b"c\nd'})    # every escape at once
    c.inc(3)
    reg.counter("requests_total", labels={"path": "plain"}).inc(2)
    reg.gauge("queue_depth").set(7.5)
    h = reg.histogram("latency_s", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    return reg


def test_prometheus_text_strict_roundtrip():
    reg = _every_kind_registry()
    text = snapshot_prometheus_text(reg.snapshot())
    parsed = parse_prometheus_text(text)      # raises on any violation

    assert parsed["types"]["requests_total"] == "counter"
    assert parsed["types"]["queue_depth"] == "gauge"
    assert parsed["types"]["latency_s"] == "histogram"

    by = {(n, tuple(sorted(lab.items()))): v
          for n, lab, v in parsed["samples"]}
    # escaped label values survive the round-trip byte-for-byte
    assert by[("requests_total",
               (("path", 'a\\b"c\nd'),))] == 3.0
    assert by[("queue_depth", ())] == 7.5
    assert by[("latency_s_count", ())] == 4.0
    assert by[("latency_s_bucket", (("le", "+Inf"),))] == 4.0

    # le buckets appear ascending with +Inf last (the parser enforces
    # it — assert the order directly too, since the JSON snapshot sorts
    # lexicographically, which would scramble "+Inf" before "0.1")
    les = [lab["le"] for n, lab, _v in parsed["samples"]
           if n == "latency_s_bucket"]
    assert les == ["0.1", "1", "10", "+Inf"]

    # strictness: duplicates, bad escapes, unordered buckets all raise
    with pytest.raises(ValueError, match="duplicate"):
        parse_prometheus_text("# TYPE a counter\na 1\na 2\n")
    with pytest.raises(ValueError, match="escape"):
        parse_prometheus_text('# TYPE a counter\na{l="\\x"} 1\n')
    with pytest.raises(ValueError, match="TYPE"):
        parse_prometheus_text("orphan_sample 1\n")
    with pytest.raises(ValueError):
        parse_prometheus_text('# TYPE h histogram\n'
                              'h_bucket{le="1"} 5\n'
                              'h_bucket{le="+Inf"} 3\n'   # not cumulative
                              'h_sum 1\nh_count 3\n')


def test_cluster_metrics_merge_adds_node_labels(tmp_path):
    tele = TelemetryStore(str(tmp_path))
    for node in ("node-a", "node-b"):
        reg = _every_kind_registry()
        reg.counter("node_specific_total", labels={"node_role": node}).inc()
        tele.write_node_metrics(reg, node)

    merged = tele.cluster_metrics()
    assert sorted(merged["nodes"]) == ["node-a", "node-b"]
    # every sample in the merged view carries its node label
    for series in merged["metrics"].values():
        for s in series["samples"]:
            assert s["labels"]["node"] in ("node-a", "node-b")

    text = tele.cluster_metrics_text()
    parsed = parse_prometheus_text(text)      # merged view stays strict
    nodes = {lab["node"] for _n, lab, _v in parsed["samples"]}
    assert nodes == {"node-a", "node-b"}
    # per-node histograms keep distinct, well-ordered bucket families
    inf = [v for n, lab, v in parsed["samples"]
           if n == "latency_s_bucket" and lab["le"] == "+Inf"]
    assert inf == [4.0, 4.0]

    # a snapshot from a future build is skipped, not merged wrongly
    doc = merge_node_snapshots({"old": _every_kind_registry().snapshot(),
                                "new": {"version": 99, "metrics": {}}})
    assert doc["nodes"] == ["old"] and doc["skipped_nodes"] == ["new"]


def test_tracer_health_metrics_in_session_snapshot(tmp_path):
    gc.collect()        # sessions share the process registry: drop the
    obs.enable("full")  # weakref'd collectors of earlier tests' stores
    sess = _seed_session(tmp_path / "s", n=600)
    sess.run(_query())
    snap = sess.metrics()["metrics"]
    modes = {s["labels"]["mode"]: s["value"]
             for s in snap["trace_mode"]["samples"]}
    assert modes == {"full": 2.0}
    assert snap["trace_spans_dropped_total"]["samples"][0]["value"] == 0.0
    # durable telemetry + watchdog surface their own health counters
    assert snap["telemetry_records"]["samples"][0]["value"] >= 1.0
    assert snap["watchdog_checks_total"]["samples"][0]["value"] >= 0.0

    obs.configure(mode="sampled", buffer=4)
    for i in range(12):
        with obs.span(f"overflow{i}"):
            pass
    snap2 = sess.metrics()["metrics"]
    assert snap2["trace_spans_dropped_total"]["samples"][0]["value"] > 0
    modes2 = {s["labels"]["mode"]: s["value"]
              for s in snap2["trace_mode"]["samples"]}
    assert modes2 == {"sampled": 1.0}


def test_session_cluster_metrics_views(tmp_path):
    gc.collect()        # see test_tracer_health_metrics_in_session_snapshot
    sess = _seed_session(tmp_path / "s", n=600)
    assert sess.export_node_metrics("me") is not None
    merged = sess.cluster_metrics()
    assert merged["nodes"] == ["me"]
    parse_prometheus_text(sess.cluster_metrics_text())
    # memory-only sessions degrade to an empty view, not an error
    mem = Session(PartitionStore(num_workers=4, backend="host"))
    assert mem.cluster_metrics()["nodes"] == []
    assert mem.export_node_metrics() is None


def test_merged_trace_is_pure_json(tmp_path):
    a = Tracer().configure(mode="full", process="p")
    with a.span("s", weird=object()):         # non-JSON arg → repr'd
        pass
    spill_spans(str(tmp_path), tracer=a)
    doc = merge_process_traces(str(tmp_path))
    text = json.dumps(doc)                    # must not raise
    assert math.isfinite(len(text))
