"""Alg. 4 matching, the workflow analyzer, and end-to-end Alg. 3."""

import numpy as np

from repro.core import (DRLSelector, GreedySelector, HistoryStore, Workload,
                        author_integrator, enumerate_candidates,
                        partitioning_creation, partitioning_match,
                        plan_shuffles)
from repro.core.dsl import reddit_loader


def _consumer_and_candidate():
    wl = author_integrator()
    c = enumerate_candidates(wl.graph, "submissions")[0]
    return wl, c


def test_match_positive():
    wl, c = _consumer_and_candidate()
    res = partitioning_match(c, "submissions", wl.graph)
    assert res.matched and len(res.partition_nodes) == 1


def test_match_negative_different_key():
    wl, _ = _consumer_and_candidate()
    other = Workload("other")
    ds = other.scan("submissions")
    other.partition(ds.parse("json")["title"])      # different key chain
    bad = enumerate_candidates(other.graph, "submissions")[0]
    assert not partitioning_match(bad, "submissions", wl.graph).matched


def test_match_negative_strategy():
    wl, _ = _consumer_and_candidate()
    rng = Workload("rng")
    ds = rng.scan("submissions")
    rng.partition(ds.parse("json")["author"], strategy="range")
    c_range = enumerate_candidates(rng.graph, "submissions")[0]
    assert not partitioning_match(c_range, "submissions", wl.graph).matched


def test_plan_shuffles_split():
    wl = author_integrator()
    subs = enumerate_candidates(wl.graph, "submissions")[0]
    elided, required = plan_shuffles(wl.graph, {"submissions": subs})
    assert len(elided) == 1 and len(required) == 1   # authors still shuffles


def test_skeleton_graph_and_consumer_enumeration():
    hist = HistoryStore()
    loader = reddit_loader("loader", "raw", "submissions", "json")
    consumer = author_integrator()
    for t in range(3):
        hist.log_workload(loader, timestamp=10.0 * t, latency=5.0,
                          input_bytes=1e9)
        hist.log_workload(consumer, timestamp=10.0 * t + 5, latency=20.0,
                          input_bytes=2e9)
    groups, edges = hist.skeleton_graph()
    assert len(groups) == 2                 # loader group + consumer group
    assert len(edges) == 1                  # loader → consumer
    consumers = hist.enumerate_consumers(loader.graph.graph_signature())
    assert len(consumers) == 1
    assert len(consumers[0].runs) == 3      # merged re-executions


def test_history_persistence(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    hist = HistoryStore(path)
    loader = reddit_loader("loader", "raw", "submissions", "json")
    hist.log_workload(loader, timestamp=1.0, latency=2.0, input_bytes=1e6)
    hist2 = HistoryStore(path)
    assert len(hist2.records) == 1
    assert hist2.records[0].app_id == "loader"


def _history_with_consumer(candidate_sig, n=3):
    hist = HistoryStore()
    loader = reddit_loader("loader", "raw", "submissions", "json")
    consumer = author_integrator()
    for t in range(n):
        hist.log_workload(loader, timestamp=100.0 * t, latency=40.0,
                          input_bytes=2e9)
        hist.log_workload(
            consumer, timestamp=100.0 * t + 50, latency=120.0,
            input_bytes=3e9,
            candidate_stats={candidate_sig: {
                "selectivity": 0.1, "distinct_keys": 1e6,
                "num_objects": 2e7}})
    return hist, loader


def test_alg3_greedy_picks_keyed_candidate():
    wl, c = _consumer_and_candidate()
    hist, loader = _history_with_consumer(c.signature())
    dec = partitioning_creation(loader, "submissions", hist,
                                selector=GreedySelector(),
                                dataset_bytes=2e9)
    assert dec.candidate.is_keyed
    assert dec.candidate.signature() == c.signature()
    assert dec.elapsed_s < 5.0              # producer-side online overhead


def test_alg3_no_history_falls_back_keyless():
    hist = HistoryStore()
    loader = reddit_loader("loader", "raw", "submissions", "json")
    dec = partitioning_creation(loader, "submissions", hist,
                                dataset_bytes=1e9)
    assert not dec.candidate.is_keyed       # only rr/random in the space


def test_alg3_drl_selector_runs():
    from repro.core.drl.agent import A3CAgent, A3CConfig
    from repro.core.features import state_dim
    wl, c = _consumer_and_candidate()
    hist, loader = _history_with_consumer(c.signature())
    agent = A3CAgent(A3CConfig(state_dim=state_dim(12), num_actions=12))
    dec = partitioning_creation(loader, "submissions", hist,
                                selector=DRLSelector(agent),
                                dataset_bytes=2e9)
    assert dec.action_index < len(dec.features)
