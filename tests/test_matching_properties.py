"""Property tests for Alg. 4: soundness + self-match completeness."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import (HistoryStore, Workload, enumerate_candidates,
                        partitioning_creation, partitioning_match)
from repro.core.advisor import GreedySelector
from repro.core.dsl import reddit_loader

ATTRS = ["a", "b", "c", "d"]


def _workload_with_chain(chain, strategy="hash"):
    wl = Workload("w")
    ds = wl.scan("data")
    col = ds
    for name in chain:
        col = col[name]
    wl.partition(col, strategy=strategy)
    wl.write(wl.map(ds, fn=None, tag="noop"), "out")
    return wl


@given(st.lists(st.sampled_from(ATTRS), min_size=0, max_size=3),
       st.lists(st.sampled_from(ATTRS), min_size=0, max_size=3))
@settings(max_examples=40, deadline=None)
def test_match_iff_same_chain(chain_a, chain_b):
    """Completeness: a stored partitioning always matches the IR it was
    extracted from.  Soundness: it matches a different IR iff the key
    chains are identical (same attr sequence)."""
    wa = _workload_with_chain(chain_a)
    wb = _workload_with_chain(chain_b)
    ca = enumerate_candidates(wa.graph, "data")[0]
    # completeness
    assert partitioning_match(ca, "data", wa.graph).matched
    # soundness
    cross = partitioning_match(ca, "data", wb.graph).matched
    assert cross == (chain_a == chain_b)


@given(st.lists(st.sampled_from(ATTRS), min_size=1, max_size=3))
@settings(max_examples=20, deadline=None)
def test_hash_never_matches_range(chain):
    h = _workload_with_chain(chain, "hash")
    r = _workload_with_chain(chain, "range")
    ch = enumerate_candidates(h.graph, "data")[0]
    assert not partitioning_match(ch, "data", r.graph).matched


def test_advisor_weighs_consumers_by_frequency():
    """Eq. 2: with two consumers wanting different keys, the advisor picks
    the key of the more frequent consumer."""
    heavy = _workload_with_chain(["a"])
    light = _workload_with_chain(["b"])
    c_heavy = enumerate_candidates(heavy.graph, "data")[0]
    c_light = enumerate_candidates(light.graph, "data")[0]
    loader = reddit_loader("loader", "raw", "data", "json")

    hist = HistoryStore()
    t = 0.0
    for _ in range(8):                      # heavy consumer: 8 runs
        hist.log_workload(loader, timestamp=t, latency=10.0, input_bytes=1e9)
        hist.log_workload(heavy, timestamp=t + 1, latency=50.0,
                          input_bytes=1e9,
                          candidate_stats={c_heavy.signature(): {
                              "selectivity": 0.1, "distinct_keys": 1e5}})
        t += 10
    hist.log_workload(light, timestamp=t, latency=50.0, input_bytes=1e9,
                      candidate_stats={c_light.signature(): {
                          "selectivity": 0.1, "distinct_keys": 1e5}})

    dec = partitioning_creation(loader, "data", hist,
                                selector=GreedySelector(),
                                dataset_bytes=1e9)
    assert dec.candidate.signature() == c_heavy.signature()
