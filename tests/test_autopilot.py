"""Autopilot service tests (DESIGN §8): observe → decide → repartition.

Covers the engine's automatic ExecutionRecords, history compaction, the
what-if cost model, generation swap consistency, advisor decision
application (host + d2d), the deterministic drift scenario via tick(),
the background thread mode, and the LRU-bounded shuffle-plan cache.
"""

import time

import numpy as np
import pytest

import repro.data.device_repartition as dr
from repro.core import (Engine, GreedySelector, HistoryStore, apply_decision,
                        author_integrator, enumerate_candidates,
                        partitioning_creation)
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore
from repro.service import (Autopilot, AutopilotConfig, LogicalClock,
                           Observer, WhatIfCostModel, drift_tables,
                           q_orderkey, run_drift_scenario)

ORDERKEY_SIG = "scan/attr:orderkey/partition[hash]"
PARTKEY_SIG = "scan/attr:partkey/partition[hash]"


def _seed_store(backend="host", **kw):
    tables = drift_tables(**kw)
    store = PartitionStore(num_workers=8, backend=backend)
    for name, data in tables.items():
        store.write(name, data)
    return store


# ---------------------------------------------------------------------------
# Observe: automatic ExecutionRecords
# ---------------------------------------------------------------------------

def test_engine_run_auto_records_history():
    store = _seed_store(n_lineitem=2000)
    hist = HistoryStore()
    eng = Engine(store)
    wl = q_orderkey()
    _, stats = eng.run(wl, history=hist, timestamp=42.0)

    assert len(hist.records) == 1
    rec = hist.records[0]
    assert rec.app_id == "q-orderkey"
    assert rec.ir_signature == wl.graph.graph_signature()
    assert rec.timestamp == 42.0
    assert rec.latency == stats.wall_s > 0
    assert rec.input_bytes == stats.input_bytes > 0
    assert rec.output_bytes == stats.output_bytes > 0
    assert rec.inputs == ["lineitem", "orders"]
    assert rec.outputs == ["q_orderkey_out"]
    # per-candidate stats measured at the partition nodes
    st = rec.candidate_stats[ORDERKEY_SIG]
    assert 0 < st["selectivity"] <= 1.0
    assert st["distinct_keys"] > 0 and st["num_objects"] > 0
    assert st["object_bytes"] >= st["key_bytes"] > 0
    # the IR is retained for candidate enumeration
    assert hist.ir_of(rec.ir_signature) is not None


def test_engine_constructor_history_and_hooks():
    store = _seed_store(n_lineitem=1000)
    hist = HistoryStore()
    eng = Engine(store, history=hist)
    seen = []
    eng.add_run_hook(lambda wl, stats: seen.append(stats))
    eng.run(q_orderkey())
    assert len(hist.records) == 1 and len(seen) == 1
    assert seen[0].candidate_stats    # hooks see the measured stats


def test_observer_attach_and_auto_compact():
    store = _seed_store(n_lineitem=1000)
    eng = Engine(store)
    obs = Observer(clock=LogicalClock(), max_records=3,
                   compact_slack=1).attach(eng)
    for _ in range(6):
        eng.run(q_orderkey())
    assert obs.records_seen == 6
    # bounded: 3 verbatim + at most one aggregate per skeleton (1 here)
    assert len(obs.history.records) <= 4
    assert sum(r.weight for r in obs.history.records) == 6.0
    assert obs.compacted_total > 0
    # timestamps are the logical clock's ticks
    assert obs.history.records[-1].timestamp == 6.0


# ---------------------------------------------------------------------------
# HistoryStore.compact
# ---------------------------------------------------------------------------

def _two_group_history(n=6, path=None):
    hist = HistoryStore(path)
    loader = reddit_loader("loader", "raw", "submissions", "json")
    consumer = author_integrator()
    c = enumerate_candidates(consumer.graph, "submissions")[0]
    for t in range(n):
        hist.log_workload(loader, timestamp=10.0 * t, latency=5.0,
                          input_bytes=1e9)
        hist.log_workload(consumer, timestamp=10.0 * t + 5, latency=20.0,
                          input_bytes=2e9,
                          candidate_stats={c.signature(): {
                              "selectivity": 0.1 + 0.01 * t,
                              "distinct_keys": 1e6 - t,
                              "num_objects": 2e7}})
    return hist, loader, consumer, c


def test_compact_bounds_log_and_preserves_aggregates():
    hist, loader, consumer, c = _two_group_history(n=6)
    assert len(hist.records) == 12
    thru_before = hist.overall_throughput()
    removed = hist.compact(max_records=4)
    assert removed > 0
    # bound: max_records verbatim + one aggregate per old skeleton (2)
    assert len(hist.records) <= 4 + 2
    assert hist.total_runs() == 12.0                 # weights preserved
    assert hist.overall_throughput() == pytest.approx(thru_before)
    # feature semantics survive: max selectivity / min distinct keys
    merged = [r for r in hist.records if r.weight > 1]
    assert merged
    for r in merged:
        if c.signature() in r.candidate_stats:
            st = r.candidate_stats[c.signature()]
            assert st["selectivity"] >= 0.1
            assert st["distinct_keys"] < 1e6
    # skeleton graph still has both groups and the producer→consumer edge
    groups, edges = hist.skeleton_graph()
    assert len(groups) == 2 and len(edges) >= 1
    # idempotent once within bounds
    assert hist.compact(max_records=len(hist.records)) == 0


def test_compact_rewrites_jsonl(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    hist, *_ = _two_group_history(n=6, path=path)
    hist.compact(max_records=2)
    reloaded = HistoryStore(path)
    assert len(reloaded.records) == len(hist.records)
    assert reloaded.total_runs() == 12.0
    assert any(r.weight > 1 for r in reloaded.records)


def test_compacted_history_keeps_advisor_decision():
    hist, loader, consumer, c = _two_group_history(n=6)
    dec_before = partitioning_creation(loader, "submissions", hist,
                                       selector=GreedySelector(),
                                       dataset_bytes=2e9)
    hist.compact(max_records=2)
    dec_after = partitioning_creation(loader, "submissions", hist,
                                      selector=GreedySelector(),
                                      dataset_bytes=2e9)
    assert dec_before.candidate.signature() == c.signature()
    assert dec_after.candidate.signature() == c.signature()


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_cost_model_calibration_and_window():
    cm = WhatIfCostModel(default_bandwidth=1e9)
    assert cm.shuffle_throughput() == 1e9            # prior
    cm.observe_shuffle(nbytes=1e6, seconds=0.01)     # 100 MB/s measured
    assert cm.shuffle_throughput() == pytest.approx(1e8)
    assert cm.repartition_throughput() == pytest.approx(1e8)  # falls back
    cm.observe_repartition(nbytes=1e6, seconds=0.02)
    assert cm.repartition_throughput() == pytest.approx(5e7)

    # window'd scoring against a real consumer IR
    hist = HistoryStore()
    wl = q_orderkey()
    for t in (1.0, 2.0, 3.0):
        hist.log_workload(wl, timestamp=t, latency=0.1, input_bytes=1e6)
    cand = enumerate_candidates(wl.graph, "lineitem")[0]
    s_all = cm.score("lineitem", 1e6, 8, cand, None, hist, now=4.0)
    assert s_all.runs_in_window == 3.0
    assert s_all.benefit_s == pytest.approx(
        3 * cm.shuffle_seconds(1e6, 8))
    s_win = cm.score("lineitem", 1e6, 8, cand, None, hist, now=4.0,
                     window_s=1.5)
    assert s_win.runs_in_window == 1.0               # only the t=3 run
    # current layout already equal → zero benefit
    s_same = cm.score("lineitem", 1e6, 8, cand, cand, hist, now=4.0)
    assert s_same.benefit_s == 0.0 and s_same.shuffles_delta == 0.0
    # hysteresis/horizon gate
    assert s_all.worth_it(1.0, horizon=4.0)
    assert not s_same.worth_it(1.0, horizon=4.0)


def test_cost_model_loads_bench_snapshot():
    import os
    bench = os.path.join(os.path.dirname(__file__), "..", "BENCH_pr2.json")
    cm = WhatIfCostModel()
    loaded = cm.load_bench_json(bench)
    assert loaded > 0
    assert cm.repartition_cal.samples == loaded
    assert cm.repartition_throughput() > 0
    # unparseable paths are a no-op, never an exception
    assert cm.load_bench_json("/nonexistent.json") == 0


# ---------------------------------------------------------------------------
# Generations: atomic swap, old-reader consistency
# ---------------------------------------------------------------------------

def test_generation_swap_keeps_old_reader_consistent():
    store = _seed_store(n_lineitem=3000)
    wl = q_orderkey()
    cand = enumerate_candidates(wl.graph, "lineitem")[0]

    reader = store.read("lineitem")                  # reader holds gen 0
    snapshot = {k: np.asarray(v).copy()
                for k, v in reader.gather().items()}
    assert reader.generation == 0

    new, moved = store.repartition(reader, cand, swap=True)
    assert moved > 0
    assert store.read("lineitem") is new
    assert new.generation == 1 and new.name == "lineitem"
    assert store.generation_of("lineitem") == 1

    # the old generation still reads bit-identically mid/post swap
    after = reader.gather()
    assert set(after) == set(snapshot)
    for k in snapshot:
        np.testing.assert_array_equal(after[k], snapshot[k])
        assert after[k].dtype == snapshot[k].dtype
    # superseded generations stay resolvable (bounded retention)
    assert store.read("lineitem", generation=0) is reader
    assert store.read("lineitem", generation=1) is new
    with pytest.raises(KeyError):
        store.read("lineitem", generation=7)


def test_generation_retention_bound():
    store = PartitionStore(num_workers=4, max_retired_generations=2)
    wl = q_orderkey()
    cand = enumerate_candidates(wl.graph, "lineitem")[0]
    store.write("lineitem", drift_tables(n_lineitem=500)["lineitem"])
    for _ in range(4):
        store.repartition(store.read("lineitem"), cand, swap=True)
    assert store.generation_of("lineitem") == 4
    store.read("lineitem", generation=3)             # retained
    with pytest.raises(KeyError):
        store.read("lineitem", generation=0)         # aged out


# ---------------------------------------------------------------------------
# Decide→apply: advisor decision applied d2d, shuffle elided, bits equal
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["host", "device"])
def test_apply_decision_end_to_end(backend):
    rng = np.random.default_rng(0)
    n_sub, n_auth = 4000, 500
    subs = {"author": rng.integers(0, n_auth, n_sub),
            "score": rng.integers(0, 100, n_sub).astype(np.float32)}
    auths = {"author": np.arange(n_auth, dtype=np.int64),
             "karma": rng.integers(0, 100, n_auth).astype(np.float32)}
    store = PartitionStore(num_workers=8, backend=backend)
    store.write("raw", subs)
    store.write("authors", auths)

    hist = HistoryStore()
    eng = Engine(store, backend=backend, history=hist)
    loader = reddit_loader("loader", "raw", "submissions", "json")
    consumer = author_integrator()
    clock = LogicalClock()
    eng.run(loader, timestamp=clock())
    vals0, st0 = eng.run(consumer, timestamp=clock())
    assert st0.shuffles_performed == 2 and st0.shuffles_elided == 0

    # Alg. 3 decision from the auto-recorded history, applied in place
    dec = partitioning_creation(loader, "submissions", hist,
                                dataset_bytes=store.read("submissions").nbytes)
    assert dec.candidate.is_keyed
    gen0 = store.generation_of("submissions")
    new, moved = apply_decision(store, dec)
    assert new.generation == gen0 + 1 and moved > 0
    if backend == "device":
        last = store.write_log[-1]
        assert last["name"] == "submissions" and last.get("path") == "d2d"

    vals1, st1 = eng.run(consumer, timestamp=clock())
    assert st1.shuffles_elided == 1                  # submissions side
    assert st1.shuffles_performed == 1               # authors still shuffles

    # bit-identical join output across generations
    join_node = max(n for n, nd in consumer.graph.nodes.items()
                    if nd.kind == "join")
    for out0, out1 in [(vals0[join_node], vals1[join_node])]:
        assert out0.num_rows == out1.num_rows
        k0 = np.lexsort((out0.columns["score"], out0.columns["author"]))
        k1 = np.lexsort((out1.columns["score"], out1.columns["author"]))
        for col in out0.columns:
            a, b = out0.columns[col][k0], out1.columns[col][k1]
            np.testing.assert_array_equal(a, b)
            assert a.dtype == b.dtype


# ---------------------------------------------------------------------------
# The drift scenario — deterministic via tick()
# ---------------------------------------------------------------------------

def _assert_drift_report(rep):
    # phase A: round-robin layout, every run pays all 3 shuffles
    assert all(r.shuffles == 3 and r.elided == 0 for r in rep.phase_a)
    # the service autonomously partitions lineitem+orders on orderkey
    applied_a = {a.dataset: a for a in rep.tick_a.applied}
    assert {"lineitem", "orders"} <= set(applied_a)
    assert applied_a["lineitem"].decision.candidate.signature() \
        == ORDERKEY_SIG
    assert applied_a["lineitem"].generation == 1
    # post-decision: both join shuffles elided, only the aggregate shuffles
    assert rep.post_a.elided == 2 and rep.post_a.shuffles == 1
    assert rep.post_a.shuffle_bytes < rep.phase_a[0].shuffle_bytes
    # bit-identical across generations
    for k in rep.result_pre_a:
        np.testing.assert_array_equal(rep.result_pre_a[k],
                                      rep.result_post_a[k])
        assert rep.result_pre_a[k].dtype == rep.result_post_a[k].dtype
    # drift: the early tick cannot flip lineitem (cooldown), the late tick
    # re-partitions it to partkey as the orderkey mix ages out of window
    assert "lineitem" not in {a.dataset for a in rep.tick_b_mid.applied}
    applied_b = {a.dataset: a for a in rep.tick_b.applied}
    assert applied_b["lineitem"].decision.candidate.signature() \
        == PARTKEY_SIG
    assert applied_b["lineitem"].generation == 2
    assert rep.lineitem_generations == [0, 1, 2]
    # post-drift: the partkey joins skip their shuffles again
    assert rep.post_b.elided == 2 and rep.post_b.shuffles == 1
    for k in rep.result_pre_b:
        np.testing.assert_array_equal(rep.result_pre_b[k],
                                      rep.result_post_b[k])


def test_drift_scenario_host_deterministic():
    rep = run_drift_scenario(backend="host")
    _assert_drift_report(rep)
    # history stayed observed throughout
    assert rep.autopilot.history.total_runs() == len(rep.phase_a) \
        + len(rep.phase_b) + 2


def test_drift_scenario_device_d2d():
    rep = run_drift_scenario(backend="device")
    _assert_drift_report(rep)
    # decisions were applied through the device-to-device fast path
    applied = {a.dataset: a for a in rep.tick_a.applied}
    assert applied["lineitem"].path == "d2d"
    applied_b = {a.dataset: a for a in rep.tick_b.applied}
    assert applied_b["lineitem"].path == "d2d"


def test_background_thread_mode():
    store = _seed_store(n_lineitem=2000)
    eng = Engine(store)
    ap = Autopilot(eng, config=AutopilotConfig(min_runs=2.0, hysteresis=0.5,
                                               cooldown_ticks=0))
    for _ in range(3):
        eng.run(q_orderkey())
    ap.start(period_s=0.02)
    try:
        deadline = time.time() + 20.0
        while time.time() < deadline:
            p = store.read("lineitem").partitioner
            if p is not None and p.is_keyed:
                break
            time.sleep(0.02)
    finally:
        ap.stop()
    assert ap.optimizer.last_error is None
    assert store.read("lineitem").partitioner.signature() == ORDERKEY_SIG
    assert store.generation_of("lineitem") >= 1


# ---------------------------------------------------------------------------
# Plan cache: LRU bound + stats reset (service longevity)
# ---------------------------------------------------------------------------

def test_plan_cache_lru_bound_and_reset():
    rng = np.random.default_rng(0)
    old_cap = dr.plan_cache_capacity()
    dr.clear_plan_cache()
    try:
        dr.set_plan_cache_capacity(2)
        for n in (100, 1000, 10_000):        # three distinct shape buckets
            cols = {"v": rng.integers(0, 99, n).astype(np.float32)}
            keys = rng.integers(0, 1_000, n).astype(np.int64)
            dr.device_rebucket(cols, keys, 8)
        stats = dr.plan_cache_stats()
        assert stats["plans"] <= 2                   # LRU bound holds
        assert stats["evictions"] >= 1
        assert stats["traces"] == 3                  # monotone incl. evicted

        dr.reset_plan_cache_stats()
        stats = dr.plan_cache_stats()
        assert stats["traces"] == 0 and stats["calls"] == 0
        assert stats["plans"] <= 2                   # plans survive a reset

        # a live plan serves without retracing after the reset
        n = 10_000
        cols = {"v": rng.integers(0, 99, n).astype(np.float32)}
        keys = rng.integers(0, 1_000, n).astype(np.int64)
        dr.device_rebucket(cols, keys, 8)
        stats = dr.plan_cache_stats()
        assert stats["calls"] == 1 and stats["traces"] == 0
    finally:
        dr.set_plan_cache_capacity(old_cap)
        dr.clear_plan_cache()


def test_plan_cache_capacity_validation():
    with pytest.raises(ValueError):
        dr.set_plan_cache_capacity(0)
