"""Engine tests: partition-aware execution, shuffle elision, correctness."""

import numpy as np
import pytest

from repro.core import (Engine, author_integrator, enumerate_candidates,
                        pagerank_iteration)
from repro.data.partition_store import PartitionStore


def _reddit_data(n_sub=5000, n_auth=1000, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32)}
    auths = {"author": np.arange(n_auth, dtype=np.int64),
             "karma": rng.normal(size=n_auth).astype(np.float32)}
    return subs, auths


def _join_oracle(subs, auths):
    karma = auths["karma"][subs["author"]]
    return subs["author"], subs["score"], karma


def _run(store_partitioned: bool):
    wl = author_integrator()
    subs, auths = _reddit_data()
    store = PartitionStore(num_workers=8)
    if store_partitioned:
        store.write("submissions", subs,
                    enumerate_candidates(wl.graph, "submissions")[0])
        store.write("authors", auths,
                    enumerate_candidates(wl.graph, "authors")[0])
    else:
        store.write("submissions", subs)
        store.write("authors", auths)
    eng = Engine(store)
    vals, stats = eng.run(wl)
    join_node = max(n for n, nd in wl.graph.nodes.items()
                    if nd.kind == "join")
    return vals[join_node], stats


def test_join_correct_and_shuffles_elided():
    out_rr, st_rr = _run(False)
    out_part, st_part = _run(True)
    assert st_rr.shuffles_performed == 2 and st_rr.shuffles_elided == 0
    assert st_part.shuffles_performed == 0 and st_part.shuffles_elided == 2
    assert st_part.shuffle_bytes == 0 and st_rr.shuffle_bytes > 0

    # both paths produce the same multiset of joined rows
    subs, auths = _reddit_data()
    oa, os_, ok = _join_oracle(subs, auths)
    for out in (out_rr, out_part):
        assert out.num_rows == len(oa)
        order = np.lexsort((out.columns["score"], out.columns["author"]))
        ref_order = np.lexsort((os_, oa))
        np.testing.assert_array_equal(out.columns["author"][order],
                                      oa[ref_order])
        np.testing.assert_allclose(out.columns["karma"][order],
                                   ok[ref_order], rtol=1e-6)


def test_pagerank_iteration_correct():
    n, fanout = 2000, 5
    rng = np.random.default_rng(1)
    neighbors = rng.integers(0, n, (n, fanout)).astype(np.int64)
    pages = {"url": np.arange(n, dtype=np.int64), "neighbors": neighbors}
    ranks = {"url": np.arange(n, dtype=np.int64),
             "rank": np.full(n, 1.0 / n, np.float64)}

    wl = pagerank_iteration()
    # emit contribs: each neighbor gets rank/fanout
    def emit(cols):
        contrib = np.repeat((cols["rank"] / fanout)[:, None], fanout, 1)
        return {"url": cols["neighbors"], "contrib": contrib}
    for node in wl.graph.nodes.values():
        if node.params.get("tag") == "emit_contribs":
            node.params["fn"] = emit

    store = PartitionStore(num_workers=4)
    store.write("pages", pages, enumerate_candidates(wl.graph, "pages")[0])
    store.write("ranks", ranks, enumerate_candidates(wl.graph, "ranks")[0])
    eng = Engine(store)
    vals, stats = eng.run(wl)
    agg_node = max(n_ for n_, nd in wl.graph.nodes.items()
                   if nd.kind == "aggregate")
    out = vals[agg_node]

    # oracle: sum of incoming rank/fanout per page
    oracle = np.zeros(n)
    np.add.at(oracle, neighbors.reshape(-1),
              np.repeat(ranks["rank"] / fanout, fanout))
    got = np.zeros(n)
    got[out.columns["key"]] = out.columns["contrib"]
    mask = oracle > 0
    np.testing.assert_allclose(got[mask], oracle[mask], rtol=1e-6)
    # pages/ranks co-partitioned on url: the join shuffles are elided, only
    # the aggregate repartition (by destination url) runs
    assert stats.shuffles_elided >= 2


def test_repartition_counts_bytes():
    subs, _ = _reddit_data(1000, 100)
    store = PartitionStore(num_workers=4)
    ds = store.write("s", subs)
    wl = author_integrator()
    c = enumerate_candidates(wl.graph, "submissions")[0]
    new, moved = store.repartition(ds, c)
    assert moved > 0
    assert new.num_rows == ds.num_rows
