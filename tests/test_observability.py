"""Observability subsystem tests (DESIGN §13): span tracer, metrics
registry, Chrome-trace exporter, schema versioning, and the Autopilot's
decision explainability (why-records).

The registry concurrency tests reuse the ``_Freeze`` sync-point barrier
from test_serving_races so a snapshot is provably taken while writer
threads are mid-stream, not after they quiesced.
"""

import gc
import json
import threading

import pytest

from repro import obs
from repro.api import Session
from repro.core import Workload
from repro.data.partition_store import PartitionStore
from repro.data.storage.durable import (DECISIONS_SCHEMA_VERSION,
                                        DurableStore)
from repro.obs.export import to_chrome_trace
from repro.obs.metrics import (METRICS_SCHEMA_VERSION, MetricsRegistry,
                               validate_snapshot)
from repro.obs.tracer import NULL_SPAN, Span, TraceContext, TRACER
from repro.service import (AutopilotConfig, LogicalClock, drift_tables,
                           q_orderkey)

from test_serving_races import _Freeze


@pytest.fixture(autouse=True)
def _tracer_reset():
    """Every test starts and ends with the process-global tracer off,
    empty, and at default capacity — tracing state must never leak
    between tests."""
    obs.configure(mode="off", buffer=65536, sample_every=16)
    obs.clear_spans()
    yield
    obs.configure(mode="off", buffer=65536, sample_every=16)
    obs.clear_spans()


def _seed_session(root=None, n=600):
    store = PartitionStore(num_workers=4, backend="host",
                           root=str(root) if root else None)
    sess = Session(store)
    for name, data in drift_tables(n_lineitem=n, n_orders=200,
                                   n_parts=80).items():
        sess.write(name, data)
    return sess


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracing_off_by_default_is_shared_noop():
    assert obs.tracing_mode() == "off"
    # the disabled path allocates nothing: every call returns the one
    # shared null span, and nothing is recorded
    sp = obs.span("anything", "cat", k=1)
    assert sp is NULL_SPAN
    assert obs.span("other") is sp
    with sp as s:
        s.set(ignored=True)
    assert obs.finished_spans() == []


def test_span_tree_parenting_and_annotations():
    obs.enable("full")
    with obs.span("root", "t", a=1) as r:
        with obs.span("child", "t") as c:
            c.set(b=2)
    spans = {s.name: s for s in obs.finished_spans()}
    assert set(spans) == {"root", "child"}
    root, child = spans["root"], spans["child"]
    assert root.parent_id is None
    assert child.parent_id == root.span_id
    assert child.trace_id == root.trace_id
    assert root.args == {"a": 1} and child.args == {"b": 2}
    assert root.dur_s >= child.dur_s >= 0
    # children nest inside the parent interval on one thread
    assert root.t0 <= child.t0 and child.t1 <= root.t1


def test_span_records_error_annotation():
    obs.enable("full")
    with pytest.raises(ValueError):
        with obs.span("boom", "t"):
            raise ValueError("x")
    (sp,) = obs.finished_spans()
    assert sp.args["error"] == "ValueError"
    assert sp.t1 is not None


def test_ring_buffer_bounds_memory():
    obs.enable("full", buffer=8)
    for i in range(30):
        with obs.span(f"s{i}", "t"):
            pass
    spans = obs.finished_spans()
    assert len(spans) == 8
    assert [s.name for s in spans] == [f"s{i}" for i in range(22, 30)]
    assert TRACER.dropped == 22
    st = TRACER.stats()
    assert st["buffered"] == 8 and st["dropped"] == 22


def test_sampled_mode_keeps_whole_trees():
    obs.enable("sampled", sample_every=3)
    for i in range(12):
        with obs.span(f"root{i}", "t"):
            with obs.span(f"child{i}", "t"):
                pass
    spans = obs.finished_spans()
    roots = {s.name for s in spans if s.name.startswith("root")}
    children = {s.name for s in spans if s.name.startswith("child")}
    assert len(roots) == 4          # 1-in-3 of 12 roots
    # a child records iff its root did — sampled traces are whole trees
    assert children == {f"child{r[len('root'):]}" for r in roots}


def test_cross_thread_parenting_and_flow():
    obs.enable("full")
    ctxs = []

    def worker(ctx):
        with TRACER.attach(ctx):
            with obs.span("work", "t"):
                pass

    with obs.span("submit", "t") as sub:
        ctx = TRACER.context()
        ctxs.append(ctx)
        t = threading.Thread(target=worker, args=(ctx,), name="w-0")
        t.start()
        t.join()
    spans = {s.name: s for s in obs.finished_spans()}
    work, submit = spans["work"], spans["submit"]
    assert work.parent_id == submit.span_id
    assert work.trace_id == submit.trace_id
    assert work.tid != submit.tid
    assert work.flow_from == ctxs[0]
    # the exporter draws the handoff as a flow-arrow pair
    ev = to_chrome_trace(obs.finished_spans())["traceEvents"]
    s = [e for e in ev if e["ph"] == "s"]
    f = [e for e in ev if e["ph"] == "f"]
    assert len(s) == len(f) == 1
    assert s[0]["id"] == f[0]["id"]
    assert s[0]["tid"] == submit.tid and f[0]["tid"] == work.tid


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("ops_total", "ops")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(4)
    g.add(-1.5)
    assert g.value == 2.5
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == [(0.1, 1), (1.0, 2)]
    assert snap["inf"] == snap["count"] == 3
    assert snap["sum"] == pytest.approx(5.55)
    # same (name, labels) resolves to the same instrument; a kind clash
    # is a hard error, not a silent shadow
    assert reg.counter("ops_total") is c
    assert reg.counter("ops_total", labels={"x": "1"}) is not c
    with pytest.raises(TypeError):
        reg.gauge("ops_total")


def test_histogram_samples_le_ascending_inf_last():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", buckets=(1.0, 0.1, 10.0))  # unsorted input
    h.observe(0.5)
    rows = list(h.samples())
    les = [dict(labels)["le"] for name, labels, _v in rows
           if name.endswith("_bucket")]
    assert les == ["0.1", "1", "10", "+Inf"]
    text = reg.prometheus_text()
    bucket_lines = [ln for ln in text.splitlines() if "_bucket" in ln]
    # the exposition must preserve that order — a lexicographic sort
    # would put +Inf first and scramble the cumulative counts
    assert [ln.split('le="')[1].split('"')[0] for ln in bucket_lines] \
        == ["0.1", "1", "10", "+Inf"]


def test_prometheus_text_shape():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "requests", labels={"tenant": "a"}).inc(3)
    reg.histogram("lat_s", "latency", buckets=(0.1,)).observe(0.05)
    text = reg.prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert '# HELP reqs_total requests' in text
    assert 'reqs_total{tenant="a"} 3' in text
    assert '# TYPE lat_s histogram' in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert 'lat_s_count 1' in text


def test_registry_concurrency_exact_totals():
    """N writer threads hammer one counter + one histogram; a snapshot is
    taken while thread 0 is provably parked mid-stream (the _Freeze sync
    point from the serving race harness), then final totals must be
    exact — no lost increments."""
    reg = MetricsRegistry()
    c = reg.counter("ops_total")
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    freeze = _Freeze()
    N, M = 8, 400

    def worker(i):
        for j in range(M):
            if i == 0 and j == M // 2:
                freeze()            # park with the other writers in flight
            c.inc()
            h.observe(0.05 * (1 + (i + j) % 3))

    threads = [threading.Thread(target=worker, args=(i,), name=f"w{i}")
               for i in range(N)]
    for t in threads:
        t.start()
    assert freeze.reached.wait(30)
    mid = h.snapshot()              # mid-flight: internally consistent
    cums = [n for _b, n in mid["buckets"]]
    assert cums == sorted(cums) and cums[-1] <= mid["count"]
    freeze.release()
    for t in threads:
        t.join(30)
    assert c.value == N * M
    snap = h.snapshot()
    assert snap["count"] == N * M
    assert snap["sum"] == pytest.approx(sum(
        0.05 * (1 + (i + j) % 3) for i in range(N) for j in range(M)))


def test_callback_weakref_lets_owner_die():
    reg = MetricsRegistry()

    class Owner:
        def samples(self):
            yield "owner_alive", {}, 1.0

    o = Owner()
    reg.register_callback(o, Owner.samples)
    assert "owner_alive" in reg.snapshot()["metrics"]
    del o
    gc.collect()
    assert "owner_alive" not in reg.snapshot()["metrics"]
    assert reg._callbacks == []     # pruned, not just skipped


def test_broken_callback_never_breaks_scrape():
    reg = MetricsRegistry()
    reg.counter("good_total").inc()

    class Bad:
        def samples(self):
            raise RuntimeError("scrape me not")

    bad = Bad()
    reg.register_callback(bad, Bad.samples)
    snap = reg.snapshot()
    assert "good_total" in snap["metrics"]


def test_snapshot_versioned_and_validated():
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    snap = reg.snapshot()
    assert snap["version"] == METRICS_SCHEMA_VERSION
    ok, msg = validate_snapshot(snap)
    assert ok and msg == ""
    ok, msg = validate_snapshot({"version": METRICS_SCHEMA_VERSION + 1})
    assert not ok and str(METRICS_SCHEMA_VERSION + 1) in msg
    ok, _ = validate_snapshot({})
    assert not ok
    json.dumps(snap)                # snapshot must be pure JSON


# ---------------------------------------------------------------------------
# Chrome-trace exporter — golden shape
# ---------------------------------------------------------------------------

def test_chrome_trace_golden_shape():
    """Hand-built spans → the exact event list the exporter must emit:
    thread metadata first, X events rebased to t=0 in µs, args carrying
    span/parent/trace ids, an s/f flow pair for the handoff, and the
    unfinished span as an explicit ``incomplete`` event whose duration
    runs to the latest known timestamp (deterministic "now")."""
    root = Span(name="root", cat="t", span_id=7, parent_id=None, trace_id=3,
                tid=10, thread_name="MainThread", t0=100.0, t1=100.005,
                args={"k": "v"})
    ctx = TraceContext(trace_id=3, span_id=7, tid=10,
                       thread_name="MainThread", captured_at=100.001)
    child = Span(name="child", cat="t", span_id=8, parent_id=7, trace_id=3,
                 tid=20, thread_name="pool-0", t0=100.002, t1=100.004,
                 args={}, flow_from=ctx)
    open_span = Span(name="open", cat="t", span_id=9, parent_id=None,
                     trace_id=4, tid=10, thread_name="MainThread",
                     t0=100.001, t1=None)   # unfinished: exported as-is
    doc = to_chrome_trace([child, root, open_span], metadata={"who": "test"})
    assert doc["traceEvents"] == [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 10,
         "args": {"name": "MainThread"}},
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 20,
         "args": {"name": "pool-0"}},
        {"ph": "X", "name": "root", "cat": "t", "pid": 1, "tid": 10,
         "ts": 0.0, "dur": 5000.0,
         "args": {"k": "v", "span_id": 7, "trace_id": 3}},
        # open span: duration-so-far up to max(t1)=100.005, flagged
        {"ph": "X", "name": "open", "cat": "t", "pid": 1, "tid": 10,
         "ts": 1000.0, "dur": 4000.0,
         "args": {"span_id": 9, "trace_id": 4, "incomplete": True}},
        {"ph": "X", "name": "child", "cat": "t", "pid": 1, "tid": 20,
         "ts": 2000.0, "dur": 2000.0,
         "args": {"span_id": 8, "parent_id": 7, "trace_id": 3}},
        {"ph": "s", "id": 1, "name": "handoff", "cat": "flow", "pid": 1,
         "tid": 10, "ts": 1000.0},
        {"ph": "f", "id": 1, "name": "handoff", "cat": "flow", "pid": 1,
         "tid": 20, "ts": 2000.0, "bp": "e"},
    ]
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["spans"] == 3
    assert doc["otherData"]["incomplete"] == 1
    assert doc["otherData"]["who"] == "test"
    # include_open=False restores the finished-only view
    doc2 = to_chrome_trace([child, root, open_span], include_open=False)
    assert doc2["otherData"]["spans"] == 2
    json.dumps(doc)


# ---------------------------------------------------------------------------
# wiring: session / planner / serving views over the registry
# ---------------------------------------------------------------------------

def test_plan_cache_stats_view_unchanged_and_in_registry():
    sess = _seed_session()
    wl = q_orderkey()
    sess.run(wl)
    sess.run(wl)
    st = sess.plan_cache_stats()
    assert {"hits", "misses", "evictions", "invalidations",
            "size"} <= set(st)
    assert all(isinstance(v, int) for v in st.values())
    assert st["hits"] == 1 and st["misses"] == 1 and st["size"] == 1
    # the same counters surface through the registry snapshot
    metrics = sess.metrics()["metrics"]
    assert "planner_plan_cache_hits_total" in metrics
    assert "store_resident_bytes" in metrics
    assert "tracer_spans_buffered" in metrics
    ok, _ = validate_snapshot(sess.metrics())
    assert ok
    text = sess.metrics_text()
    assert "# TYPE planner_plan_cache_hits_total counter" in text


def test_session_trace_covers_all_layers(tmp_path):
    obs.enable("full")
    sess = _seed_session(tmp_path / "store")
    sess.run(q_orderkey())
    names = {s.name for s in obs.finished_spans()}
    assert {"session.run", "planner.lookup", "planner.compile", "exec.run",
            "exec.scan", "exec.partition", "store.write",
            "store.install", "durable.persist"} <= names
    path = tmp_path / "trace.json"
    doc = sess.export_trace(str(path))
    assert path.exists()
    loaded = json.loads(path.read_text())
    assert loaded["otherData"]["session_backend"] == doc[
        "otherData"]["session_backend"] == "host"
    # everything the run touched parents under one session.run tree
    # (the seed writes before it are their own roots)
    by_id = {e["args"]["span_id"]: e for e in doc["traceEvents"]
             if e["ph"] == "X"}
    (run,) = [e for e in by_id.values() if e["name"] == "session.run"]
    tree = [e for e in by_id.values()
            if e["args"]["trace_id"] == run["args"]["trace_id"]]
    assert len(tree) >= 5
    assert all(e is run or "parent_id" in e["args"] for e in tree)


def _query() -> Workload:
    wl = Workload("obs-serve-q")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    wl.aggregate(j, key=j["odate"], reducer="sum")
    return wl


def test_serving_ticket_spans_cross_thread_and_latency_histogram():
    obs.enable("full")
    sess = _seed_session()
    front = sess.serve(max_workers=2)
    try:
        for _ in range(3):
            front.run(_query(), block=True, timeout=60)
    finally:
        front.close()
    spans = obs.finished_spans()
    submits = [s for s in spans if s.name == "serve.submit"]
    tickets = [s for s in spans if s.name == "serve.ticket"]
    assert len(submits) == len(tickets) == 3
    by_id = {s.span_id: s for s in spans}
    for t in tickets:
        # ticket spans parent across the pool handoff, with a flow link
        assert t.flow_from is not None
        parent = by_id[t.parent_id]
        assert parent.tid != t.tid
    assert {s.args["outcome"] for s in submits} == {"admitted"}
    # the latency histogram recorded every completed ticket
    snap = front.metrics()["metrics"]
    counts = snap["serving_latency_seconds_count"]["samples"]
    assert sum(s["value"] for s in counts) == 3
    assert 'serving_latency_seconds_bucket' in front.metrics_text()
    assert any(s["value"] == 3 for s in
               snap["serving_completed"]["samples"])


# ---------------------------------------------------------------------------
# decisions.log schema versioning
# ---------------------------------------------------------------------------

def test_decisions_log_version_tolerance(tmp_path):
    st = DurableStore(str(tmp_path / "root"))
    st.log_decision({"kind": "applied"})
    with open(st.decisions_path, "a") as f:
        # a row from a future build, a pre-versioning (v1) row, a torn tail
        f.write(json.dumps({"kind": "future",
                            "version": DECISIONS_SCHEMA_VERSION + 1}) + "\n")
        f.write(json.dumps({"kind": "legacy"}) + "\n")
        f.write('{"torn')
    with pytest.warns(RuntimeWarning, match="skipped 1 row"):
        rows = st.decisions()
    assert st.skipped_decisions == 1
    assert [r["kind"] for r in rows] == ["applied", "legacy"]
    assert rows[0]["version"] == DECISIONS_SCHEMA_VERSION
    assert "version" not in rows[1]          # v1 rows pass through as-is


# ---------------------------------------------------------------------------
# Autopilot decision explainability (why-records)
# ---------------------------------------------------------------------------

def _run_autopilot(root, **cfg_kw):
    sess = _seed_session(root, n=1500)
    cfg = AutopilotConfig(**cfg_kw)
    ap = sess.autopilot(clock=LogicalClock(), config=cfg)
    for _ in range(4):
        sess.run(q_orderkey())
    return sess, ap, ap.tick()


def test_why_records_explain_accepted_decisions(tmp_path):
    # hysteresis=0: worth_it needs only a positive measured benefit, so
    # acceptance doesn't hinge on wall-clock ratios in a loaded process
    sess, ap, rep = _run_autopilot(tmp_path / "s", min_runs=2.0,
                                   hysteresis=0.0)
    assert rep.applied and rep.why
    accepted = [w for w in rep.why if w["accepted"]]
    assert {a.dataset for a in rep.applied} == {w["dataset"]
                                                for w in accepted}
    for w in rep.why:
        assert w["kind"] == "why"
        gate_names = [g["gate"] for g in w["gates"]]
        assert "worth_it" in gate_names and "min_runs" in gate_names
        assert w["accepted"] == all(g["passed"] for g in w["gates"])
        # the priced score carries the full gate math
        s = w["score"]
        assert s["apply_cost_s"] == pytest.approx(
            s["repartition_s"] + s["io_s"])
        assert s["gated_cost_s"] == pytest.approx(
            s["hysteresis"] * s["apply_cost_s"])
    assert sess.explain_decisions() == ap.explain(limit=50)


def test_why_records_explain_rejections(tmp_path):
    # min_runs higher than the observed run count: every candidate must be
    # rejected, and the why-record must name the failing gate with its
    # observed-vs-required numbers
    sess, _ap, rep = _run_autopilot(tmp_path / "s", min_runs=100.0)
    assert not rep.applied and rep.why
    for w in rep.why:
        assert not w["accepted"]
        (mr,) = [g for g in w["gates"] if g["gate"] == "min_runs"]
        assert not mr["passed"]
        assert mr["observed"] < mr["required"] == 100.0


def test_why_records_survive_into_fresh_session(tmp_path):
    root = tmp_path / "s"
    _sess, ap, rep = _run_autopilot(root, min_runs=2.0)
    # a fresh session over the same durable root explains past decisions
    # from decisions.log without any attached autopilot
    sess2 = Session(PartitionStore(num_workers=4, backend="host",
                                   root=str(root)))
    recs = sess2.explain_decisions()
    assert recs == ap.explain()
    # and the batched row itself is version-stamped
    row = [r for r in sess2.store.durable.decisions()
           if r.get("kind") == "why"][-1]
    assert row["version"] == DECISIONS_SCHEMA_VERSION
    assert row["count"] == len(rep.why)


def test_autopilot_tick_spans(tmp_path):
    obs.enable("full")
    _sess, _ap, rep = _run_autopilot(tmp_path / "s", min_runs=2.0,
                                     hysteresis=0.0)
    assert rep.applied          # apply spans below must not be vacuous
    spans = obs.finished_spans()
    ticks = [s for s in spans if s.name == "autopilot.tick"]
    applies = [s for s in spans if s.name == "autopilot.apply"]
    assert len(ticks) == 1
    assert ticks[0].args["considered"] == len(rep.considered)
    assert ticks[0].args["applied"] == len(rep.applied) == len(applies)
    tick_id = ticks[0].span_id
    assert all(a.parent_id == tick_id for a in applies)
    for a in applies:
        assert a.args["kind"] in ("repartition", "salt", "rebucket")
        assert "generation" in a.args
