"""Sharding advisor selects the argmin-dominant-term candidate."""

from repro.core.sharding_advisor import (ShardingCandidate, advise,
                                         dominant_term)


def test_advise_picks_min_dominant_term():
    fake_results = {
        "baseline": {"compute_s": 1.0, "memory_s": 5.0, "collective_s": 2.0},
        "cache_seq_shard": {"compute_s": 1.0, "memory_s": 3.0,
                            "collective_s": 0.5},
        "flash_decode": {"compute_s": 1.0, "memory_s": 4.0,
                         "collective_s": 2.0},
    }

    def fake_analyze(arch, shape, multi_pod=False, extra_cfg=None,
                     variant=None, verbose=False):
        variant = variant or {}
        if variant.get("cache_seq_shard"):
            return dict(fake_results["cache_seq_shard"])
        if variant.get("flash_decode"):
            return dict(fake_results["flash_decode"])
        return dict(fake_results["baseline"])

    dec = advise("qwen1.5-110b", "decode_32k", analyze=fake_analyze)
    assert dec.winner.name == "cache_seq_shard"
    assert dec.dominant_term_s == 3.0
    assert len(dec.trail) == 3


def test_advise_skips_failing_candidates():
    def flaky(arch, shape, multi_pod=False, extra_cfg=None, variant=None,
              verbose=False):
        if variant:
            raise RuntimeError("did not lower")
        return {"compute_s": 1.0, "memory_s": 1.0, "collective_s": 1.0}

    dec = advise("qwen1.5-110b", "decode_32k", analyze=flaky)
    assert dec.winner.name == "baseline"
    assert any("error" in t for t in dec.trail)


def test_dominant_term():
    assert dominant_term({"compute_s": 1, "memory_s": 9,
                          "collective_s": 3}) == 9
