"""Optimizer, checkpoint, data pipeline, runtime (FT/elastic/straggler)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.data.pipeline import DataConfig, PrefetchingLoader, TokenSource
from repro.optimizer.adamw import AdamW, global_norm
from repro.optimizer.compression import (compress_int8, compress_topk,
                                         init_error_feedback)
from repro.optimizer.schedule import warmup_cosine
from repro.runtime.elastic import MeshPlan, replan_mesh, resharding_plan
from repro.runtime.fault_tolerance import Coordinator, RunState
from repro.runtime.straggler import StragglerMitigator


# -- optimizer -----------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping():
    opt = AdamW(lr=1e-3, grad_clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    new_params, _ = opt.update(huge, state, params)
    assert float(jnp.abs(new_params["w"]).max()) < 1.0


def test_bf16_moments_dtype():
    opt = AdamW(lr=1e-3, state_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    new_params, new_state = opt.update({"w": jnp.ones(4, jnp.bfloat16)},
                                       state, params)
    assert new_state.v["w"].dtype == jnp.bfloat16
    assert new_params["w"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    fn = warmup_cosine(1e-3, 100, 1000)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(100)) - 1e-3) < 1e-9
    assert float(fn(1000)) < float(fn(500)) < float(fn(100))


# -- gradient compression ----------------------------------------------------------

@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
@settings(max_examples=25, deadline=None)
def test_int8_error_feedback_preserves_signal(vals):
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    ef = init_error_feedback(g)
    # applying compression twice with EF: residual carries what was lost
    deq1, ef, wire = compress_int8(g, ef)
    deq2, ef, _ = compress_int8(g, ef)
    total = np.asarray(deq1["w"]) + np.asarray(deq2["w"])
    expect = 2 * np.array(vals, np.float32)
    scale = max(1.0, np.abs(expect).max())
    assert np.abs(total - expect).max() / scale < 0.05
    assert wire < g["w"].size * 4          # fewer wire bytes than fp32


def test_topk_compression_sparsity():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 100, dtype=np.float32))}
    ef = init_error_feedback(g)
    deq, ef, wire = compress_topk(g, ef, frac=0.1)
    nz = int((np.asarray(deq["w"]) != 0).sum())
    assert nz <= 12
    assert np.abs(np.asarray(ef.residual["w"])).sum() > 0


# -- checkpoint -------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, state, extra={"data_step": s * 10})
    assert latest_step(d) == 4
    assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 3
    restored, step, extra = restore_checkpoint(d, state)
    assert step == 4 and extra["data_step"] == 40
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_atomicity(tmp_path):
    """A leftover temp dir from a crashed writer never corrupts restore."""
    d = str(tmp_path)
    state = {"w": jnp.ones(3)}
    save_checkpoint(d, 1, state)
    os.makedirs(os.path.join(d, ".tmp_ckpt_crashed"), exist_ok=True)
    restored, step, _ = restore_checkpoint(d, state)
    assert step == 1


# -- data pipeline -----------------------------------------------------------------

def test_token_source_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8,
                     num_hosts=4)
    src = TokenSource(cfg)
    b1 = src.global_batch_at(5)
    b2 = src.global_batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host-sharded == concatenation of per-host shards (exactly-once replays)
    shard2 = src.batch_at(5, 2)
    np.testing.assert_array_equal(b1["tokens"][4:6], shard2["tokens"])


def test_prefetching_loader_order():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4)
    loader = PrefetchingLoader(TokenSource(cfg), start_step=3)
    steps = [next(loader)[0] for _ in range(4)]
    loader.close()
    assert steps == [3, 4, 5, 6]


# -- fault tolerance / elastic / straggler ----------------------------------------------

def test_coordinator_detects_failure_and_recovers():
    coord = Coordinator(num_workers=4, miss_threshold=2)
    for step in range(1, 3):
        for w in (0, 1, 2):            # worker 3 silent
            coord.heartbeat(w, step)
        ev = coord.tick(step, checkpoint_step=0)
    assert ev is not None and ev.worker == 3
    assert coord.state == RunState.RECOVERING
    assert coord.alive_workers() == [0, 1, 2]
    coord.recover()
    assert coord.state == RunState.RUNNING


def test_elastic_replan_preserves_model_axis():
    plan = replan_mesh(MeshPlan((16, 16), ("data", "model")), 200)
    assert plan.shape == (8, 16)
    plan2 = replan_mesh(MeshPlan((2, 16, 16), ("pod", "data", "model")), 300)
    assert plan2.shape[-1] == 16 and plan2.num_devices <= 300
    with pytest.raises(ValueError):
        replan_mesh(MeshPlan((16, 16), ("data", "model")), 8)


def test_resharding_plan_covers_batch():
    old = MeshPlan((16, 16), ("data", "model"))
    new = MeshPlan((8, 16), ("data", "model"))
    plan = resharding_plan(old, new, batch_dim=256)
    rows = [a["rows"] for a in plan["assignments"]]
    assert rows[0][0] == 0 and rows[-1][1] == 256
    assert all(r1[1] == r2[0] for r1, r2 in zip(rows, rows[1:]))


def test_straggler_reissue_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, num_hosts=4)
    src = TokenSource(cfg)
    mit = StragglerMitigator()
    fetches = []

    def fetch(step, host):
        fetches.append((step, host))
        return src.batch_at(step, host)

    for i in range(10):                 # warm the latency window
        mit.fetch_shard(fetch, i, host=0, backup_host=1,
                        simulated_latency=0.1)
    out = mit.fetch_shard(fetch, 99, host=0, backup_host=1,
                          simulated_latency=10.0)   # straggles
    assert mit.reissues == 1
    np.testing.assert_array_equal(out["tokens"],
                                  src.batch_at(99, 0)["tokens"])


def test_train_restart_exactly_once(tmp_path):
    """Failure mid-run: restart from checkpoint replays the same batches."""
    cfg = DataConfig(vocab_size=50, seq_len=4, global_batch=2)
    src = TokenSource(cfg)
    seen = []
    ckpt_dir = str(tmp_path)
    state = {"acc": jnp.zeros(())}

    def run(start, fail_at=None):
        s, st_ = start, state
        if latest_step(ckpt_dir) is not None:
            st_, s, _ = restore_checkpoint(ckpt_dir, state)
        while s < 6:
            if fail_at is not None and s == fail_at:
                raise RuntimeError("node died")
            batch = src.global_batch_at(s)
            seen.append((s, int(batch["tokens"][0, 0])))
            st_ = {"acc": st_["acc"] + batch["tokens"].sum()}
            s += 1
            save_checkpoint(ckpt_dir, s, st_)
        return st_

    try:
        run(0, fail_at=3)
    except RuntimeError:
        pass
    final = run(0)
    # steps 0..5 each contribute exactly once to the surviving lineage
    replayed = [s for s, _ in seen]
    assert replayed == [0, 1, 2, 3, 4, 5]
    expect = sum(int(src.global_batch_at(s)["tokens"].sum())
                 for s in range(6))
    assert int(final["acc"]) == expect
